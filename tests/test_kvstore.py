"""KVStore correctness: paper §6 semantics + Appendix C linearizability,
checked against a sequential oracle over the induced linearization order
(GETs at their pre-round remote read; modifications in ticket order).

Windowed histories (``op_window``) replay against the same oracle in the
window-induced total order: GETs at the window start, mutations in
(participant, window slot) lexicographic order.  ``op_round`` — the public
B=1 wrapper — is additionally pinned bit-for-bit against the retained
scalar reference implementation on randomized traces."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (DELETE, GET, INSERT, NOP, UPDATE, KVStore,
                        make_manager)

P = 4
S = 4          # slots per node
W = 2          # value words
LOCKS = 2

mgr = make_manager(P)
kv = KVStore(None, "kv", mgr, slots_per_node=S, value_width=W,
             num_locks=LOCKS, index_capacity=64)


@jax.jit
def step(st, op, key, val):
    return mgr.runtime.run(kv.op_round, st, op, key, val)


@jax.jit
def ref_step(st, op, key, val):
    return mgr.runtime.run(kv._op_round_reference, st, op, key, val)


@jax.jit
def window_step(st, op, key, val):
    return mgr.runtime.run(kv.op_window, st, op, key, val)


def drive(rounds):
    """rounds: list of per-participant op lists [(op, key, value), ...]."""
    st = kv.init_state()
    outs = []
    for ops in rounds:
        op = jnp.asarray([o[0] for o in ops], jnp.int32)
        key = jnp.asarray([o[1] for o in ops], jnp.uint32)
        val = jnp.asarray([o[2] for o in ops], jnp.int32)
        st, res = step(st, op, key, val)
        outs.append(jax.tree.map(np.asarray, res))
    return st, outs


def drive_windows(windows, store_mgr=None, store=None, state=None):
    """windows: list of rounds; each round is a per-participant list of
    equal-length windows [(op, key, value), ...]."""
    skv = store or kv
    st = skv.init_state() if state is None else state
    wstep = window_step if store is None else jax.jit(
        lambda s, o, k, v: store_mgr.runtime.run(skv.op_window, s, o, k, v))
    outs = []
    for w in windows:
        op = jnp.asarray([[o[0] for o in lane] for lane in w], jnp.int32)
        key = jnp.asarray([[o[1] for o in lane] for lane in w], jnp.uint32)
        val = jnp.asarray([[o[2] for o in lane] for lane in w], jnp.int32)
        st, res = wstep(st, op, key, val)
        outs.append(jax.tree.map(np.asarray, res))
    return st, outs


class Oracle:
    """Sequential replay in the linearization order the channel induces."""

    def __init__(self, n_participants=P, slots=S):
        self.map = {}
        self.free = [slots] * n_participants
        self.loc = {}

    def _mod(self, p, op, key, val):
        """Apply one mutation at its linearization point; returns success."""
        if op == INSERT:
            if key not in self.map and self.free[p] > 0:
                self.map[key] = tuple(val)
                self.loc[key] = p
                self.free[p] -= 1
                return True
        elif op == UPDATE:
            if key in self.map:
                self.map[key] = tuple(val)
                return True
        elif op == DELETE:
            if key in self.map:
                del self.map[key]
                self.free[self.loc.pop(key)] += 1
                return True
        return False

    def apply_round(self, ops):
        pre = dict(self.map)
        results = [None] * len(ops)
        for p, (op, key, val) in enumerate(ops):
            if op == GET:
                results[p] = pre.get(key)
        for p, (op, key, val) in enumerate(ops):
            if op in (INSERT, UPDATE, DELETE):
                results[p] = self._mod(p, op, key, val)
        return results

    def apply_window(self, window):
        """Window-induced order: GETs at the window start; mutations in
        (participant, window slot) lexicographic order."""
        pre = dict(self.map)
        results = [[None] * len(lane) for lane in window]
        for p, lane in enumerate(window):
            for b, (op, key, val) in enumerate(lane):
                if op == GET:
                    results[p][b] = pre.get(key)
        for p, lane in enumerate(window):
            for b, (op, key, val) in enumerate(lane):
                if op in (INSERT, UPDATE, DELETE):
                    results[p][b] = self._mod(p, op, key, val)
        return results


def assert_lookup_pinned(store, store_mgr, st, keys=range(1, 33)):
    """Pin the O(PROBE) hash probe bit-for-bit against the O(C) flat scan
    on the store's current index state (found, pos, node, slot, ctr — all
    five lanes, including the pos-0 convention for missing keys)."""
    ks = jnp.asarray(list(keys), jnp.uint32)

    @jax.jit
    def both(st, ks):
        def prog(s, k):
            a = jax.vmap(lambda q: store._index_lookup_hash(s, q))(k)
            b = jax.vmap(lambda q: store._index_lookup_reference(s, q))(k)
            return a, b
        return store_mgr.runtime.run(prog, st, jnp.broadcast_to(
            ks, (store.P,) + ks.shape))

    a, b = both(st, ks)
    for la, lb in zip(a, b):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))


def check_windows_against_oracle(windows, store_mgr=None, store=None):
    skv, smgr = (store or kv), (store_mgr or mgr)
    _st, outs = drive_windows(windows, store_mgr=store_mgr, store=store)
    assert_lookup_pinned(skv, smgr, _st)
    oracle = Oracle(slots=skv.S)
    for rnd, (w, res) in enumerate(zip(windows, outs)):
        expect = oracle.apply_window(w)
        for p, lane in enumerate(w):
            for b, (op, key, val) in enumerate(lane):
                if op == NOP:
                    continue
                if op == GET:
                    exp = expect[p][b]
                    assert bool(res.found[p][b]) == (exp is not None), \
                        f"window {rnd} p{p}b{b} GET({key}) found mismatch"
                    if exp is not None:
                        np.testing.assert_array_equal(res.value[p][b], exp)
                else:
                    assert bool(res.found[p][b]) == expect[p][b], \
                        f"window {rnd} p{p}b{b} op{op}({key}) ok mismatch"


def check_against_oracle(rounds):
    _st, outs = drive(rounds)
    assert_lookup_pinned(kv, mgr, _st)
    oracle = Oracle()
    for rnd, (ops, res) in enumerate(zip(rounds, outs)):
        expect = oracle.apply_round(ops)
        for p, (op, key, val) in enumerate(ops):
            if op == NOP:
                continue
            if op == GET:
                exp = expect[p]
                assert bool(res.found[p]) == (exp is not None), \
                    f"round {rnd} p{p} GET({key}) found mismatch"
                if exp is not None:
                    np.testing.assert_array_equal(res.value[p], exp)
            else:
                assert bool(res.found[p]) == expect[p], \
                    f"round {rnd} p{p} op{op}({key}) ok mismatch"


def v(key, salt=0):
    return (int(key) * 10 + salt, int(key) * 100 + salt)


NOPR = (NOP, 1, (0, 0))


class TestKVStoreBasic:
    def test_insert_then_get(self):
        check_against_oracle([
            [(INSERT, 5, v(5)), NOPR, NOPR, NOPR],
            [NOPR, (GET, 5, v(0)), NOPR, NOPR],
        ])

    def test_get_missing_returns_empty(self):
        check_against_oracle([[NOPR, NOPR, (GET, 9, v(0)), NOPR]])

    def test_update_and_delete_lifecycle(self):
        check_against_oracle([
            [(INSERT, 3, v(3)), NOPR, NOPR, NOPR],
            [NOPR, (UPDATE, 3, v(3, 7)), NOPR, (GET, 3, v(0))],
            [(GET, 3, v(0)), NOPR, (DELETE, 3, v(0)), NOPR],
            [NOPR, (GET, 3, v(0)), NOPR, (UPDATE, 3, v(3, 9))],
        ])

    def test_concurrent_inserts_distinct_keys(self):
        check_against_oracle([
            [(INSERT, k, v(k)) for k in (1, 2, 3, 4)],
            [(GET, k, v(0)) for k in (4, 3, 2, 1)],
        ])

    def test_concurrent_insert_same_key_one_wins(self):
        check_against_oracle([
            [(INSERT, 7, v(7, 1)), (INSERT, 7, v(7, 2)),
             (INSERT, 7, v(7, 3)), NOPR],
            [(GET, 7, v(0)), NOPR, NOPR, NOPR],
        ])

    def test_same_round_insert_get_sees_pre_state(self):
        check_against_oracle([
            [(INSERT, 2, v(2)), (GET, 2, v(0)), NOPR, NOPR],
            [(GET, 2, v(0)), (DELETE, 2, v(0)), NOPR, NOPR],
        ])

    def test_contended_lock_stripe_serializes(self):
        # keys 2 and 4 share lock stripe (2 % 2 == 4 % 2)
        check_against_oracle([
            [(INSERT, 2, v(2)), (INSERT, 4, v(4)),
             (UPDATE, 2, v(2, 5)), (DELETE, 4, v(0))],
            [(GET, 2, v(0)), (GET, 4, v(0)), NOPR, NOPR],
        ])

    def test_capacity_exhaustion_fails_insert(self):
        rounds = []
        # participant 0 inserts S+1 keys mapping to its own slots
        for i in range(S + 1):
            rounds.append([(INSERT, 10 + i, v(10 + i)), NOPR, NOPR, NOPR])
        check_against_oracle(rounds)

    def test_slot_reuse_after_delete(self):
        check_against_oracle([
            [(INSERT, 11, v(11)), NOPR, NOPR, NOPR],
            [(DELETE, 11, v(0)), NOPR, NOPR, NOPR],
            [(INSERT, 13, v(13)), NOPR, NOPR, NOPR],
            [(GET, 11, v(0)), (GET, 13, v(0)), NOPR, NOPR],
        ])


class TestAppendixCValidation:
    """Direct checks of the read-path case analysis (Appendix C)."""

    def _seed_state(self):
        st = kv.init_state()
        op = jnp.asarray([INSERT, NOP, NOP, NOP], jnp.int32)
        key = jnp.asarray([5, 1, 1, 1], jnp.uint32)
        val = jnp.asarray([v(5), (0, 0), (0, 0), (0, 0)], jnp.int32)
        st, _ = step(st, op, key, val)
        return st

    def _get5(self, st):
        op = jnp.asarray([NOP, GET, NOP, NOP], jnp.int32)
        key = jnp.asarray([1, 5, 1, 1], jnp.uint32)
        val = jnp.zeros((P, W), jnp.int32)
        _st, res = step(st, op, key, val)
        return jax.tree.map(np.asarray, res)

    def test_case1_valid_read_returns_value(self):
        res = self._get5(self._seed_state())
        assert res.found[1]
        np.testing.assert_array_equal(res.value[1], v(5))

    def test_case2_torn_row_retries_then_empty(self):
        st = self._seed_state()
        # corrupt the stored row at its host (inserter was participant 0):
        buf = np.asarray(st.rows.buf).copy()
        slot = np.nonzero(buf[0, :, W + 1] == 1)[0][0]  # valid row at node 0
        buf[0, slot, 0] ^= 0x5A5A  # tear the payload, checksum now stale
        st = st._replace(rows=st.rows._replace(buf=jnp.asarray(buf)))
        res = self._get5(st)
        assert not res.found[1]
        assert res.retries[1] == 3  # MAX_GET_RETRIES exhausted

    def test_case3_invalid_bit_returns_empty(self):
        st = self._seed_state()
        buf = np.asarray(st.rows.buf).copy()
        slot = np.nonzero(buf[0, :, W + 1] == 1)[0][0]
        row = buf[0, slot].copy()
        row[W + 1] = 0  # unset valid bit, re-checksum (a mid-insert snapshot)
        from repro.core.ownedvar import checksum as cks
        row[W + 2] = np.asarray(
            jax.lax.bitcast_convert_type(cks(jnp.asarray(row[:W + 2])),
                                         jnp.int32))
        buf[0, slot] = row
        st = st._replace(rows=st.rows._replace(buf=jnp.asarray(buf)))
        res = self._get5(st)
        assert not res.found[1]
        assert res.retries[1] == 0  # clean read, EMPTY by case 3

    def test_case4_counter_mismatch_returns_empty(self):
        from repro.core.kvstore import IDX_CTR, IDX_KEY
        st = self._seed_state()
        # stale local index at participant 1: ctr behind the slot's counter
        idx = np.asarray(st.idx).copy()
        pos = np.nonzero(idx[1, :, IDX_KEY] == 5)[0][0]
        idx[1, pos, IDX_CTR] -= 1
        st = st._replace(idx=jnp.asarray(idx))
        res = self._get5(st)
        assert not res.found[1]
        assert res.retries[1] == 0


class TestKVStoreRandomized:
    @pytest.mark.parametrize("seed", range(6))
    def test_random_batches_match_oracle(self, seed):
        rng = np.random.default_rng(seed)
        keys = list(range(1, 7))
        rounds = []
        for rnd in range(6):
            ops = []
            for p in range(P):
                op = int(rng.choice([NOP, GET, INSERT, UPDATE, DELETE],
                                    p=[.1, .3, .3, .15, .15]))
                key = int(rng.choice(keys))
                ops.append((op, key, v(key, rnd)))
            rounds.append(ops)
        check_against_oracle(rounds)


class TestWindowedOps:
    """op_window linearizability: windowed histories vs the oracle replayed
    in the window-induced total order."""

    def test_window_insert_then_get_roundtrip(self):
        check_windows_against_oracle([
            [[(INSERT, 1, v(1)), (INSERT, 2, v(2))],
             [(INSERT, 3, v(3)), (INSERT, 4, v(4))],
             [NOPR, NOPR], [NOPR, NOPR]],
            [[(GET, 4, v(0)), (GET, 3, v(0))],
             [(GET, 2, v(0)), (GET, 9, v(0))],
             [(GET, 1, v(0)), NOPR], [NOPR, (GET, 2, v(0))]],
        ])

    def test_window_gets_linearize_at_window_start(self):
        # the UPDATE lands within the window; every GET lane (any slot,
        # any participant) still observes the pre-window value.
        check_windows_against_oracle([
            [[(INSERT, 5, v(5))], [NOPR], [NOPR], [NOPR]],
            [[(UPDATE, 5, v(5, 9)), (GET, 5, v(0))],
             [(GET, 5, v(0)), (GET, 5, v(0))], [NOPR, NOPR], [NOPR, NOPR]],
            [[(GET, 5, v(0))], [NOPR], [NOPR], [NOPR]],
        ])

    def test_delete_insert_same_key_one_window(self):
        # within one participant's window: window order (delete, then
        # re-insert) — both succeed, slot recycled through the free stack.
        check_windows_against_oracle([
            [[(INSERT, 7, v(7))], [NOPR], [NOPR], [NOPR]],
            [[(DELETE, 7, v(0)), (INSERT, 7, v(7, 2))],
             [NOPR, NOPR], [NOPR, NOPR], [NOPR, NOPR]],
            [[(GET, 7, v(0))], [NOPR], [NOPR], [NOPR]],
        ])

    def test_cross_participant_same_key_participant_then_window_order(self):
        # key 6 absent.  p0 INSERTs it at window slot 1; p1 DELETEs it at
        # window slot 0.  Per-lock FIFO is (participant, slot) order, so
        # p0's (later-slot) insert precedes p1's (earlier-slot) delete —
        # both succeed.  A window-major order would fail both.
        check_windows_against_oracle([
            [[NOPR, (INSERT, 6, v(6))],
             [(DELETE, 6, v(0)), NOPR], [NOPR, NOPR], [NOPR, NOPR]],
            [[(GET, 6, v(0))], [NOPR], [NOPR], [NOPR]],
        ])

    @pytest.mark.parametrize("seed", range(4))
    def test_random_windows_match_oracle(self, seed):
        rng = np.random.default_rng(100 + seed)
        keys = list(range(1, 7))
        B = 3
        windows = []
        for rnd in range(4):
            w = []
            for p in range(P):
                lane = []
                for b in range(B):
                    op = int(rng.choice(
                        [NOP, GET, INSERT, UPDATE, DELETE],
                        p=[.1, .3, .3, .15, .15]))
                    key = int(rng.choice(keys))
                    lane.append((op, key, v(key, rnd * B + b)))
                w.append(lane)
            windows.append(w)
        check_windows_against_oracle(windows)

    def test_window_equals_op_round_sequence(self):
        """On histories whose windows have no cross-lane conflicts (each key
        mutated by one lane; GET keys unmutated in that window) and no
        capacity pressure (a window-mode insert allocates before a
        concurrent delete's slot GC lands), op_window is observably
        equivalent to running the window slots as successive op_rounds."""
        emgr = make_manager(P)
        ekv = KVStore(None, "kv_equiv", emgr, slots_per_node=32,
                      value_width=W, num_locks=LOCKS, index_capacity=256)
        estep = jax.jit(lambda s, o, k, vv: emgr.runtime.run(
            ekv.op_round, s, o, k, vv))
        rng = np.random.default_rng(7)
        B = 3
        windows = []
        live = set()
        for rnd in range(4):
            pool = list(range(1, 20))
            rng.shuffle(pool)
            w = []
            for p in range(P):
                lane = []
                for b in range(B):
                    key = pool.pop()   # unique key per lane in this window
                    if key in live:
                        op = int(rng.choice([GET, UPDATE, DELETE],
                                            p=[.4, .4, .2]))
                        if op == DELETE:
                            live.discard(key)
                    else:
                        op = int(rng.choice([GET, INSERT], p=[.3, .7]))
                        if op == INSERT:
                            live.add(key)
                    lane.append((op, key, v(key, rnd * B + b)))
                w.append(lane)
            windows.append(w)

        st_w, outs_w = drive_windows(windows, store_mgr=emgr, store=ekv)
        # replay the same histories as B successive op_rounds per window
        st_s = ekv.init_state()
        outs_s = []
        for w in windows:
            per_lane = []
            for b in range(B):
                ops = [lane[b] for lane in w]
                op = jnp.asarray([o[0] for o in ops], jnp.int32)
                key = jnp.asarray([o[1] for o in ops], jnp.uint32)
                val = jnp.asarray([o[2] for o in ops], jnp.int32)
                st_s, res = estep(st_s, op, key, val)
                per_lane.append(jax.tree.map(np.asarray, res))
            outs_s.append(per_lane)
        for rnd, (w, res_w, res_s) in enumerate(
                zip(windows, outs_w, outs_s)):
            for p, lane in enumerate(w):
                for b, (op, key, val) in enumerate(lane):
                    if op == NOP:
                        continue
                    assert bool(res_w.found[p][b]) == \
                        bool(res_s[b].found[p]), \
                        f"window {rnd} p{p}b{b} op{op}({key})"
                    np.testing.assert_array_equal(res_w.value[p][b],
                                                  res_s[b].value[p])
        # both executions agree on the final logical contents
        probe = jnp.broadcast_to(
            jnp.arange(1, 21, dtype=jnp.uint32), (P, 20))

        @jax.jit
        def probe_all(st, keys):
            _st, v, f = emgr.runtime.run(lambda s, k: ekv.get_batch(s, k),
                                         st, keys)
            return v, f

        vw, fw = probe_all(st_w, probe)
        vs, fs = probe_all(st_s, probe)
        np.testing.assert_array_equal(np.asarray(fw), np.asarray(fs))
        np.testing.assert_array_equal(np.asarray(vw), np.asarray(vs))

    @pytest.mark.parametrize("seed", range(3))
    def test_op_round_bitidentical_to_reference(self, seed):
        """Acceptance regression: op_window with B=1 (== public op_round)
        is bit-identical to the retained scalar reference implementation —
        full state pytree and results — on randomized mixed-op traces."""
        rng = np.random.default_rng(40 + seed)
        keys = list(range(1, 7))
        st_a = st_b = kv.init_state()
        for rnd in range(6):
            ops = []
            for p in range(P):
                op = int(rng.choice([NOP, GET, INSERT, UPDATE, DELETE],
                                    p=[.1, .3, .3, .15, .15]))
                key = int(rng.choice(keys))
                ops.append((op, key, v(key, rnd)))
            op = jnp.asarray([o[0] for o in ops], jnp.int32)
            key = jnp.asarray([o[1] for o in ops], jnp.uint32)
            val = jnp.asarray([o[2] for o in ops], jnp.int32)
            st_a, res_a = step(st_a, op, key, val)
            st_b, res_b = ref_step(st_b, op, key, val)
            for la, lb in zip(jax.tree.leaves(st_a), jax.tree.leaves(st_b)):
                np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))
            for la, lb in zip(jax.tree.leaves(res_a._asdict()),
                              jax.tree.leaves(res_b._asdict())):
                np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))


class TestWindowEdgeCases:
    def test_insert_window_exceeds_free_stack(self):
        # p0 inserts S+2 distinct keys in one window: exactly S land (the
        # earliest queue positions), the rest report found=False.
        B = S + 2
        w = [[(INSERT, 10 + b, v(10 + b)) for b in range(B)]] + \
            [[NOPR] * B for _ in range(P - 1)]
        st, outs = drive_windows([w])
        found = outs[0].found[0]
        assert found.sum() == S
        assert not found[S:].any(), "capacity failures are the excess ops"
        # the survivors are readable; the failed keys are absent
        gets = [[(GET, 10 + b, v(0)) for b in range(B)]] + \
            [[NOPR] * B for _ in range(P - 1)]
        _st2, outs2 = drive_windows([w, gets])
        np.testing.assert_array_equal(outs2[1].found[0], found)

    def test_index_overflow_reports_failure_and_latches(self):
        smgr = make_manager(P)
        skv = KVStore(None, "kv_tinyidx", smgr, slots_per_node=S,
                      value_width=W, num_locks=LOCKS, index_capacity=2)
        w = [[(INSERT, k, v(k)) for k in (1, 2, 3)]] + \
            [[NOPR] * 3 for _ in range(P - 1)]
        st, outs = drive_windows([w], store_mgr=smgr, store=skv)
        found = outs[0].found[0]
        np.testing.assert_array_equal(found, [True, True, False])
        assert bool(np.asarray(st.idx_overflow).all()), \
            "overflow latches on every participant's index replica"
        # the un-indexed insert returned its slot to the inserter's stack
        np.testing.assert_array_equal(np.asarray(st.free_top),
                                      [S - 2] + [S] * (P - 1))

    def test_delete_and_reinsert_full_stack_same_window(self):
        # fill p0 completely, then delete one key and insert a fresh one in
        # the same window (delete's lock FIFO slot precedes the insert):
        # the freed slot is recycled within the window.
        fill = [[(INSERT, 10 + b, v(10 + b)) for b in range(S)]] + \
            [[NOPR] * S for _ in range(P - 1)]
        w2 = [[(DELETE, 10, v(0)), (INSERT, 30, v(30))]] + \
            [[NOPR, NOPR] for _ in range(P - 1)]
        probe = [[(GET, 10, v(0)), (GET, 30, v(0))]] + \
            [[NOPR, NOPR] for _ in range(P - 1)]
        _st, outs = drive_windows([fill, w2, probe])
        np.testing.assert_array_equal(outs[1].found[0], [True, True])
        np.testing.assert_array_equal(outs[2].found[0], [False, True])


class TestRowEncoding:
    """Property tests for encode_row/decode_row (deterministic mirror of the
    hypothesis suite in test_properties.py, so they run without dev deps)."""

    @pytest.mark.parametrize("seed", range(4))
    def test_checksum_catches_any_single_word_tear(self, seed):
        rng = np.random.default_rng(seed)
        payload = rng.integers(-2**31, 2**31 - 1, size=W, dtype=np.int64)
        row = np.asarray(kv.encode_row(
            jnp.asarray(payload, jnp.int32),
            jnp.uint32(rng.integers(0, 2**32, dtype=np.uint64)),
            bool(rng.integers(0, 2))))
        _p, _c, _v, ok = kv.decode_row(jnp.asarray(row))
        assert bool(ok), "untorn row must validate"
        for pos in range(W + 2):           # any body word
            delta = int(rng.integers(1, 2**31 - 1))
            torn = row.copy()
            torn[pos] = np.int32(np.int64(torn[pos]) ^ delta)
            if np.array_equal(torn, row):
                continue
            _p, _c, _v, ok = kv.decode_row(jnp.asarray(torn))
            assert not bool(ok), f"tear at word {pos} must break checksum"

    def test_decode_case_analysis_elementwise(self):
        """Appendix C cases over a batched row set, vmapped elementwise:
        clean+valid, clean+invalid (mid-insert/post-delete), torn."""
        val = jnp.asarray(v(3), jnp.int32)
        rows = jnp.stack([
            kv.encode_row(val, jnp.uint32(5), True),    # case 1: valid
            kv.encode_row(val, jnp.uint32(5), False),   # case 3: invalid bit
            kv.encode_row(val, jnp.uint32(4), True),    # case 4: stale ctr
            kv.encode_row(val, jnp.uint32(5), True).at[0].add(1),  # case 2
        ])
        payload, ctr, valid, ok = jax.vmap(kv.decode_row)(rows)
        np.testing.assert_array_equal(np.asarray(valid),
                                      [True, False, True, True])
        np.testing.assert_array_equal(np.asarray(ok),
                                      [True, True, True, False])
        # index holds ctr=5: the GET-level accept mask is found only for 0
        accept = np.asarray(ok) & np.asarray(valid) & \
            (np.asarray(ctr) == 5)
        np.testing.assert_array_equal(accept, [True, False, False, False])
        np.testing.assert_array_equal(np.asarray(payload)[0], v(3))


def _np_hash32(x):
    """Numpy mirror of kvstore._hash_u32 (lowbias32), for crafting keys."""
    x = np.asarray(x, np.uint64)
    x = (x ^ (x >> np.uint64(16))) & np.uint64(0xFFFFFFFF)
    x = (x * np.uint64(0x7FEB352D)) & np.uint64(0xFFFFFFFF)
    x = (x ^ (x >> np.uint64(15))) & np.uint64(0xFFFFFFFF)
    x = (x * np.uint64(0x846CA68B)) & np.uint64(0xFFFFFFFF)
    return ((x ^ (x >> np.uint64(16))) & np.uint64(0xFFFFFFFF)).astype(
        np.uint32)


def _keys_in_bucket(C, bucket, n, start=1):
    """First n keys ≥ start whose hash lands in ``bucket`` (mod C)."""
    out, k = [], start
    while len(out) < n:
        if int(_np_hash32(k)) % C == bucket:
            out.append(k)
        k += 1
    return out


def _recs(*entries):
    """Tracker records from (kind, key, node, slot, ctr) tuples."""
    r = np.zeros((len(entries), 5), np.int32)
    for i, (kind, key, node, slot, ctr) in enumerate(entries):
        r[i] = [kind, key, node, slot, ctr]
    return r


class _ApplyHarness:
    """Drive _apply_tracker variants directly (unit level, vmap binding)."""

    def __init__(self, C=8, S=16, probe=None):
        self.mgr = make_manager(P)
        self.kv = KVStore(None, f"kv_apply_c{C}_{probe}_{id(self)}",
                          self.mgr, slots_per_node=S, value_width=W,
                          num_locks=LOCKS, index_capacity=C,
                          index_max_probe=probe)
        self._vec = jax.jit(lambda s, r: self.mgr.runtime.run(
            self.kv._apply_tracker_vectorized, s, r))
        self._seq = jax.jit(lambda s, r: self.mgr.runtime.run(
            self.kv._apply_tracker_reference, s, r))

    def init(self):
        return self.kv.init_state()

    def apply(self, st, recs_np, variant="vec"):
        recs = jnp.asarray(np.broadcast_to(recs_np, (P,) + recs_np.shape))
        fn = self._vec if variant == "vec" else self._seq
        st, applied = fn(st, recs)
        return st, np.asarray(applied)[0]

    def lookup(self, st, keys, impl="hash"):
        ks = jnp.broadcast_to(jnp.asarray(keys, jnp.uint32),
                              (P, len(keys)))
        fn = {"hash": self.kv._index_lookup_hash,
              "ref": self.kv._index_lookup_reference}[impl]

        @jax.jit
        def run(st, ks):
            return self.mgr.runtime.run(
                lambda s, k: jax.vmap(lambda q: fn(s, q))(k), st, ks)

        out = run(st, ks)
        return jax.tree.map(lambda x: np.asarray(x)[0], out)


class TestHashIndex:
    """Unit tests of the open-addressing index through the tracker-apply
    path, each cross-checked bit-for-bit against _index_lookup_reference."""

    def _pin(self, h, st, keys):
        a = h.lookup(st, keys, "hash")
        b = h.lookup(st, keys, "ref")
        for la, lb in zip(a, b):
            np.testing.assert_array_equal(la, lb)

    def test_collision_chain_probes_through(self):
        C = 8
        h = _ApplyHarness(C=C)
        ks = _keys_in_bucket(C, 3, 3)       # three keys, same bucket
        st, applied = h.apply(h.init(), _recs(
            *[(1, k, i % P, i, 1) for i, k in enumerate(ks)]))
        assert applied.all()
        found, _pos, node, slot, _ctr = h.lookup(st, ks)
        assert found.all(), "all chain members reachable through the chain"
        np.testing.assert_array_equal(slot, np.arange(len(ks)))
        self._pin(h, st, ks + [99, 100])

    def test_probe_wraparound(self):
        C = 8
        h = _ApplyHarness(C=C)
        # fill the tail buckets so a chain starting near C-1 must wrap
        ks = _keys_in_bucket(C, C - 1, 3)
        st, applied = h.apply(h.init(), _recs(
            *[(1, k, 0, i, 1) for i, k in enumerate(ks)]))
        assert applied.all()
        pos = h.lookup(st, ks)[1]
        assert (pos < C).all() and pos[0] == C - 1 and (pos[1:] < C - 1).all(), \
            "chain wrapped past C-1 to the front of the table"
        found = h.lookup(st, ks)[0]
        assert found.all()
        self._pin(h, st, ks)

    def test_delete_reinsert_through_tombstones(self):
        C = 8
        h = _ApplyHarness(C=C)
        k1, k2, k3 = _keys_in_bucket(C, 5, 3)
        st, _ = h.apply(h.init(), _recs((1, k1, 0, 0, 1), (1, k2, 1, 1, 1)))
        # delete the chain head: k2 must stay reachable (tombstone, not
        # EMPTY, so the probe does not terminate early)
        st, applied = h.apply(st, _recs((2, k1, 0, 0, 1)))
        assert applied.all()
        found, _pos, _n, slot, _c = h.lookup(st, [k1, k2])
        np.testing.assert_array_equal(found, [False, True])
        # a fresh insert reclaims the tombstone at the chain head
        st, applied = h.apply(st, _recs((1, k3, 2, 2, 1)))
        assert applied.all()
        found, pos3, _n, slot3, _c = h.lookup(st, [k3])
        assert found[0] and pos3[0] == int(_np_hash32(k1)) % C, \
            "reinsert through the tombstone reclaims the freed position"
        self._pin(h, st, [k1, k2, k3])

    def test_load_factor_one_overflow_latches(self):
        C = 4
        h = _ApplyHarness(C=C)      # PROBE == C: window covers the table
        st, applied = h.apply(h.init(), _recs(
            *[(1, 10 + i, 0, i, 1) for i in range(C)]))
        assert applied.all(), "C inserts fill the table to load factor 1"
        assert not np.asarray(st.idx_overflow).any()
        st, applied = h.apply(st, _recs((1, 99, 0, C, 1)))
        assert not applied.any(), "insert into a full table fails"
        assert np.asarray(st.idx_overflow).all(), \
            "overflow latches on every participant's replica"
        # the table is unchanged and still fully readable
        found = h.lookup(st, [10 + i for i in range(C)])[0]
        assert found.all()
        self._pin(h, st, [10 + i for i in range(C)] + [99])

    def test_bounded_probe_overflow_before_capacity(self):
        # PROBE < C: a clustered window can overflow while the table still
        # has free positions elsewhere — the documented bounded-probe trade
        C, PROBE = 16, 4
        h = _ApplyHarness(C=C, probe=PROBE)
        ks = _keys_in_bucket(C, 7, PROBE + 1)
        st, applied = h.apply(h.init(), _recs(
            *[(1, k, 0, i, 1) for i, k in enumerate(ks)]))
        np.testing.assert_array_equal(applied, [True] * PROBE + [False])
        assert np.asarray(st.idx_overflow).all()


class TestTrackerApplyEquivalence:
    """Vectorized wave scheduler vs the sequential reference sweep on
    adversarial same-key record chains: same applied flags, same logical
    key → (node, slot, ctr) mapping (via the flat scan, which is layout-
    agnostic), same free-stack effects, same overflow latch."""

    def _check(self, recs_np, C=8, S=16, hv=None, hs=None):
        hv = hv or _ApplyHarness(C=C, S=S)
        hs = hs or _ApplyHarness(C=C, S=S)
        st_v, app_v = hv.apply(hv.init(), recs_np, "vec")
        st_s, app_s = hs.apply(hs.init(), recs_np, "seq")
        np.testing.assert_array_equal(app_v, app_s)
        keys = sorted(set(int(r[1]) for r in recs_np)) + [999]
        lv = hv.lookup(st_v, keys, "ref")
        ls = hs.lookup(st_s, keys, "ref")
        # logical equality: found everywhere; node/slot/ctr wherever found
        # (positions may differ — hash vs flat placement policies — and a
        # missing key's pos-0 row is layout junk in both)
        np.testing.assert_array_equal(lv[0], ls[0], err_msg="found")
        fnd = np.asarray(lv[0], bool)
        for name, a, b in zip("node slot ctr".split(), lv[2:], ls[2:]):
            np.testing.assert_array_equal(np.asarray(a)[fnd],
                                          np.asarray(b)[fnd], err_msg=name)
        np.testing.assert_array_equal(np.asarray(st_v.free_top),
                                      np.asarray(st_s.free_top))
        np.testing.assert_array_equal(np.asarray(st_v.free_stack),
                                      np.asarray(st_s.free_stack))
        np.testing.assert_array_equal(np.asarray(st_v.idx_overflow),
                                      np.asarray(st_s.idx_overflow))

    def test_same_key_insert_delete_insert_chain(self):
        self._check(_recs((1, 7, 0, 0, 1), (2, 7, 0, 0, 1),
                          (1, 7, 1, 3, 2)))

    def test_interleaved_chains_and_distinct_keys(self):
        self._check(_recs(
            (1, 5, 0, 0, 1), (1, 6, 1, 1, 1), (2, 5, 0, 0, 1),
            (1, 5, 2, 2, 2), (2, 6, 1, 1, 1), (1, 8, 3, 3, 1),
            (2, 8, 3, 3, 1), (1, 6, 0, 4, 2)))

    def test_delete_miss_and_dead_records(self):
        self._check(_recs((0, 1, 0, 0, 0), (2, 42, 0, 0, 1),
                          (1, 3, 0, 1, 1), (0, 2, 0, 0, 0),
                          (2, 3, 0, 1, 1)))

    def test_host_slot_gc_order_matches(self):
        # multiple deletes hosted at different nodes: free-stack pushes in
        # record order at each host
        recs = _recs(*[(1, 10 + i, i % P, i, 1) for i in range(8)])
        hv, hs = _ApplyHarness(C=32), _ApplyHarness(C=32)
        st_v, _ = hv.apply(hv.init(), recs, "vec")
        st_s, _ = hs.apply(hs.init(), recs, "seq")
        dels = _recs(*[(2, 10 + i, i % P, i, 1) for i in (5, 1, 3, 7)])
        st_v, av = hv.apply(st_v, dels, "vec")
        st_s, as_ = hs.apply(st_s, dels, "seq")
        np.testing.assert_array_equal(av, as_)
        np.testing.assert_array_equal(np.asarray(st_v.free_stack),
                                      np.asarray(st_s.free_stack))
        np.testing.assert_array_equal(np.asarray(st_v.free_top),
                                      np.asarray(st_s.free_top))

    @pytest.mark.parametrize("seed", range(4))
    def test_random_valid_chains(self, seed):
        """Randomized protocol-valid record streams (same-key records
        alternate insert/delete, as the lock FIFO guarantees)."""
        rng = np.random.default_rng(200 + seed)
        live = {}
        entries = []
        slot_ctr = 0
        for _ in range(12):
            key = int(rng.integers(1, 7))
            if live.get(key):
                entries.append((2, key) + live[key])
                live[key] = None
            else:
                loc = (int(rng.integers(0, P)), slot_ctr % 16, slot_ctr + 1)
                slot_ctr += 1
                entries.append((1, key) + loc)
                live[key] = loc
        self._check(_recs(*entries), C=16)


class TestBatchedGets:
    def test_get_batch_matches_individual_gets(self):
        st = kv.init_state()
        rounds = [[(INSERT, k, v(k)) for k in (1, 2, 3, 4)],
                  [(INSERT, k, v(k)) for k in (5, 6, 1, 2)]]  # 1,2 fail
        for ops in rounds:
            op = jnp.asarray([o[0] for o in ops], jnp.int32)
            key = jnp.asarray([o[1] for o in ops], jnp.uint32)
            val = jnp.asarray([o[2] for o in ops], jnp.int32)
            st, _ = step(st, op, key, val)

        @jax.jit
        def batch_get(st, keys):
            _st, v, f = mgr.runtime.run(
                lambda s, k: kv.get_batch(s, k), st, keys)
            return v, f

        keys = jnp.asarray([[1, 2, 3, 9], [5, 6, 9, 1],
                            [4, 4, 4, 4], [9, 9, 9, 9]], jnp.uint32)
        values, found = batch_get(st, keys)
        values, found = np.asarray(values), np.asarray(found)
        expect_found = np.array([[1, 1, 1, 0], [1, 1, 0, 1],
                                 [1, 1, 1, 1], [0, 0, 0, 0]], bool)
        np.testing.assert_array_equal(found, expect_found)
        np.testing.assert_array_equal(values[0, 0], v(1))
        np.testing.assert_array_equal(values[2, 3], v(4))


# ------------------------------------------------------- read tier (§8)
cmgr = make_manager(P)
ckv = KVStore(None, "kv_cached", cmgr, slots_per_node=S, value_width=W,
              num_locks=LOCKS, index_capacity=64, cache_slots=64)


@jax.jit
def cached_window_step(st, op, key, val):
    return cmgr.runtime.run(ckv.op_window, st, op, key, val)


@jax.jit
def cached_get_batch(st, keys, preds):
    return cmgr.runtime.run(
        lambda s, k, p: ckv.get_batch(s, k, pred=p), st, keys, preds)


@jax.jit
def cached_vs_reference_reads(st, keys):
    """Both read paths on the SAME state: the cached tier and the retained
    uncached specification.  Returns ((values, found) cached,
    (values, found) reference)."""
    def prog(s, k):
        pred = jnp.ones(k.shape, jnp.bool_)
        cv, cf, _ct, _cache = ckv._get_window(s, k, pred)
        rv, rf, _rt = ckv._get_window_reference(s, k, pred)
        return (cv, cf), (rv, rf)
    return cmgr.runtime.run(prog, st, keys)


def _drive_cached(windows):
    st = ckv.init_state()
    outs = []
    for w in windows:
        op = jnp.asarray([[o[0] for o in lane] for lane in w], jnp.int32)
        key = jnp.asarray([[o[1] for o in lane] for lane in w], jnp.uint32)
        val = jnp.asarray([[o[2] for o in lane] for lane in w], jnp.int32)
        st, res = cached_window_step(st, op, key, val)
        outs.append(jax.tree.map(np.asarray, res))
    return st, outs


class TestReadTier:
    """The locality-managed read tier (DESIGN.md §8): counter-validated
    cache + coalesced verb, pinned against the uncached specification and
    checked for coherence under every mutation pattern."""

    def test_cached_store_windows_match_oracle(self):
        """The full oracle suite runs against a cache-enabled store: the
        tier must be observably invisible."""
        check_windows_against_oracle([
            [[(INSERT, 1, v(1)), (INSERT, 2, v(2))],
             [(INSERT, 3, v(3)), (INSERT, 4, v(4))],
             [NOPR, NOPR], [NOPR, NOPR]],
            [[(GET, 4, v(0)), (GET, 3, v(0))],
             [(GET, 2, v(0)), (GET, 9, v(0))],
             [(GET, 1, v(0)), NOPR], [NOPR, (GET, 2, v(0))]],
            # the same reads again: served from the cache, same answers
            [[(GET, 4, v(0)), (GET, 3, v(0))],
             [(GET, 2, v(0)), (GET, 9, v(0))],
             [(GET, 1, v(0)), NOPR], [NOPR, (GET, 2, v(0))]],
            # mutate under the cached rows, then re-read
            [[(UPDATE, 4, v(4, 7)), (DELETE, 3, v(0))],
             [NOPR, NOPR], [NOPR, NOPR], [NOPR, NOPR]],
            [[(GET, 4, v(0)), (GET, 3, v(0))],
             [(GET, 4, v(0)), (GET, 3, v(0))],
             [(GET, 4, v(0)), NOPR], [NOPR, (GET, 4, v(0))]],
        ], store_mgr=cmgr, store=ckv)

    @pytest.mark.parametrize("seed", range(4))
    def test_random_cached_windows_match_oracle(self, seed):
        rng = np.random.default_rng(300 + seed)
        keys = list(range(1, 7))
        B = 3
        windows = []
        for rnd in range(5):
            w = []
            for p in range(P):
                lane = []
                for b in range(B):
                    op = int(rng.choice(
                        [NOP, GET, INSERT, UPDATE, DELETE],
                        p=[.1, .35, .25, .15, .15]))
                    key = int(rng.choice(keys))
                    lane.append((op, key, v(key, rnd * B + b)))
                w.append(lane)
            windows.append(w)
        check_windows_against_oracle(windows, store_mgr=cmgr, store=ckv)

    @pytest.mark.parametrize("seed", range(4))
    def test_cached_reads_pinned_bitwise_to_reference_under_mutation(
            self, seed):
        """Acceptance: after EVERY window of a randomized interleaved
        mutation history, the cached ``_get_window`` and the uncached
        ``_get_window_reference`` return bit-identical (values, found) on
        the same state — the cache never serves anything the wire would
        not."""
        rng = np.random.default_rng(400 + seed)
        keys = list(range(1, 7))
        probe = jnp.broadcast_to(
            jnp.arange(1, 9, dtype=jnp.uint32), (P, 8))
        st = ckv.init_state()
        for rnd in range(6):
            op = rng.choice([NOP, GET, INSERT, UPDATE, DELETE],
                            size=(P, 2), p=[.1, .3, .25, .2, .15])
            kk = rng.choice(keys, size=(P, 2))
            vv = np.stack([kk * 11 + rnd, kk * 13 + rnd],
                          axis=-1).astype(np.int32)
            st, _res = cached_window_step(
                st, jnp.asarray(op, jnp.int32), jnp.asarray(kk, jnp.uint32),
                jnp.asarray(vv))
            (cv, cf), (rv, rf) = cached_vs_reference_reads(st, probe)
            np.testing.assert_array_equal(np.asarray(cf), np.asarray(rf))
            np.testing.assert_array_equal(np.asarray(cv), np.asarray(rv))

    def test_update_invalidates_cached_row(self):
        # participant 0 inserts key 5; everyone caches it; participant 2
        # updates it; every cached copy must be dropped (same slot ctr!)
        w_ins = [[(INSERT, 5, v(5))]] + [[NOPR]] * (P - 1)
        w_get = [[(GET, 5, v(0))] for _ in range(P)]
        w_upd = [[NOPR], [NOPR], [(UPDATE, 5, (42, 43))], [NOPR]]
        _st, outs = _drive_cached([w_ins, w_get, w_get, w_upd, w_get])
        for p in range(P):
            np.testing.assert_array_equal(outs[1].value[p][0], v(5))
            np.testing.assert_array_equal(outs[2].value[p][0], v(5))
            np.testing.assert_array_equal(outs[4].value[p][0], (42, 43))

    def test_delete_invalidates_cached_row(self):
        w_ins = [[(INSERT, 5, v(5))]] + [[NOPR]] * (P - 1)
        w_get = [[(GET, 5, v(0))] for _ in range(P)]
        w_del = [[NOPR], [(DELETE, 5, v(0))], [NOPR], [NOPR]]
        _st, outs = _drive_cached([w_ins, w_get, w_del, w_get])
        assert all(bool(outs[1].found[p][0]) for p in range(P))
        assert not any(bool(outs[3].found[p][0]) for p in range(P))

    def test_slot_reuse_bumps_counter_past_cache(self):
        # delete key 5 and re-insert key 7 into the SAME slot: a stale
        # cached row for (node, slot) fails counter validation for 7 and
        # the index lookup already fails for 5.
        w_ins = [[(INSERT, 5, v(5))]] + [[NOPR]] * (P - 1)
        w_get5 = [[(GET, 5, v(0))] for _ in range(P)]
        w_cycle = [[(DELETE, 5, v(0)), (INSERT, 7, v(7))]] + \
            [[NOPR, NOPR]] * (P - 1)
        w_get = [[(GET, 5, v(0)), (GET, 7, v(0))] for _ in range(P)]
        st, outs = _drive_cached([w_ins, w_get5, w_cycle, w_get])
        for p in range(P):
            assert not bool(outs[3].found[p][0])
            assert bool(outs[3].found[p][1])
            np.testing.assert_array_equal(outs[3].value[p][1], v(7))

    def test_warm_reads_cost_zero_wire_bytes_and_count_hits(self):
        st = ckv.init_state()
        w_ins = [[(INSERT, 1 + p, v(1 + p))] for p in range(P)]
        op = jnp.asarray([[o[0] for o in lane] for lane in w_ins], jnp.int32)
        kk = jnp.asarray([[o[1] for o in lane] for lane in w_ins], jnp.uint32)
        vv = jnp.asarray([[o[2] for o in lane] for lane in w_ins], jnp.int32)
        st, _res = cached_window_step(st, op, kk, vv)
        keys = jnp.broadcast_to(jnp.arange(1, 1 + P, dtype=jnp.uint32),
                                (P, P))
        preds = jnp.ones((P, P), jnp.bool_)
        cmgr.traffic.enable().reset()
        fresh = jax.jit(lambda s, k, p: cmgr.runtime.run(
            lambda ss, kk, pp: ckv.get_batch(ss, kk, pred=pp), s, k, p))
        st, _v, f = fresh(st, keys, preds)
        jax.block_until_ready(f)
        assert bool(jnp.all(f))
        cold = cmgr.traffic.total_bytes()
        cmgr.traffic.reset()
        st, _v, f = fresh(st, keys, preds)
        jax.block_until_ready(f)
        warm = cmgr.traffic.total_bytes()
        cs = cmgr.traffic.cache_summary()["kv_cached.readcache"]
        cmgr.traffic.disable().reset()
        assert bool(jnp.all(f))
        assert cold > 0.0
        assert warm == 0.0, "all-hit window must put nothing on the wire"
        # P participants × (P-1) remote lanes each, all hits on the warm call
        assert cs["hits"] == P * (P - 1) and cs["hit_rate"] == 1.0

    def test_get_batch_pred_masks_lanes(self):
        st = ckv.init_state()
        op = jnp.asarray([[INSERT]] * P, jnp.int32)
        kk = jnp.asarray([[1 + p] for p in range(P)], jnp.uint32)
        vv = jnp.asarray([[v(1 + p)] for p in range(P)], jnp.int32)
        st, _res = cached_window_step(st, op, kk, vv)
        keys = jnp.broadcast_to(jnp.arange(1, 5, dtype=jnp.uint32), (P, 4))
        preds = jnp.asarray(np.tile([True, False, True, False], (P, 1)))
        st, vals, found = cached_get_batch(st, keys, preds)
        found, vals = np.asarray(found), np.asarray(vals)
        assert found[:, 0].all() and found[:, 2].all()
        assert not found[:, 1].any() and not found[:, 3].any()
        np.testing.assert_array_equal(vals[:, 1], np.zeros((P, W)))
        for p in range(P):
            np.testing.assert_array_equal(vals[p, 0], v(1))
            np.testing.assert_array_equal(vals[p, 2], v(3))
