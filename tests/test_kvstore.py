"""KVStore correctness: paper §6 semantics + Appendix C linearizability,
checked against a sequential oracle over the induced linearization order
(GETs at their pre-round remote read; modifications in ticket order)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (DELETE, GET, INSERT, NOP, UPDATE, KVStore,
                        make_manager)

P = 4
S = 4          # slots per node
W = 2          # value words
LOCKS = 2

mgr = make_manager(P)
kv = KVStore(None, "kv", mgr, slots_per_node=S, value_width=W,
             num_locks=LOCKS, index_capacity=64)


@jax.jit
def step(st, op, key, val):
    return mgr.runtime.run(kv.op_round, st, op, key, val)


def drive(rounds):
    """rounds: list of per-participant op lists [(op, key, value), ...]."""
    st = kv.init_state()
    outs = []
    for ops in rounds:
        op = jnp.asarray([o[0] for o in ops], jnp.int32)
        key = jnp.asarray([o[1] for o in ops], jnp.uint32)
        val = jnp.asarray([o[2] for o in ops], jnp.int32)
        st, res = step(st, op, key, val)
        outs.append(jax.tree.map(np.asarray, res))
    return st, outs


class Oracle:
    """Sequential replay in the linearization order the channel induces."""

    def __init__(self):
        self.map = {}
        self.free = [S] * P
        self.loc = {}

    def apply_round(self, ops):
        pre = dict(self.map)
        results = [None] * len(ops)
        for p, (op, key, val) in enumerate(ops):
            if op == GET:
                results[p] = pre.get(key)
        for p, (op, key, val) in enumerate(ops):
            ok = False
            if op == INSERT:
                if key not in self.map and self.free[p] > 0:
                    self.map[key] = tuple(val)
                    self.loc[key] = p
                    self.free[p] -= 1
                    ok = True
            elif op == UPDATE:
                if key in self.map:
                    self.map[key] = tuple(val)
                    ok = True
            elif op == DELETE:
                if key in self.map:
                    del self.map[key]
                    self.free[self.loc.pop(key)] += 1
                    ok = True
            if op in (INSERT, UPDATE, DELETE):
                results[p] = ok
        return results


def check_against_oracle(rounds):
    _st, outs = drive(rounds)
    oracle = Oracle()
    for rnd, (ops, res) in enumerate(zip(rounds, outs)):
        expect = oracle.apply_round(ops)
        for p, (op, key, val) in enumerate(ops):
            if op == NOP:
                continue
            if op == GET:
                exp = expect[p]
                assert bool(res.found[p]) == (exp is not None), \
                    f"round {rnd} p{p} GET({key}) found mismatch"
                if exp is not None:
                    np.testing.assert_array_equal(res.value[p], exp)
            else:
                assert bool(res.found[p]) == expect[p], \
                    f"round {rnd} p{p} op{op}({key}) ok mismatch"


def v(key, salt=0):
    return (int(key) * 10 + salt, int(key) * 100 + salt)


NOPR = (NOP, 1, (0, 0))


class TestKVStoreBasic:
    def test_insert_then_get(self):
        check_against_oracle([
            [(INSERT, 5, v(5)), NOPR, NOPR, NOPR],
            [NOPR, (GET, 5, v(0)), NOPR, NOPR],
        ])

    def test_get_missing_returns_empty(self):
        check_against_oracle([[NOPR, NOPR, (GET, 9, v(0)), NOPR]])

    def test_update_and_delete_lifecycle(self):
        check_against_oracle([
            [(INSERT, 3, v(3)), NOPR, NOPR, NOPR],
            [NOPR, (UPDATE, 3, v(3, 7)), NOPR, (GET, 3, v(0))],
            [(GET, 3, v(0)), NOPR, (DELETE, 3, v(0)), NOPR],
            [NOPR, (GET, 3, v(0)), NOPR, (UPDATE, 3, v(3, 9))],
        ])

    def test_concurrent_inserts_distinct_keys(self):
        check_against_oracle([
            [(INSERT, k, v(k)) for k in (1, 2, 3, 4)],
            [(GET, k, v(0)) for k in (4, 3, 2, 1)],
        ])

    def test_concurrent_insert_same_key_one_wins(self):
        check_against_oracle([
            [(INSERT, 7, v(7, 1)), (INSERT, 7, v(7, 2)),
             (INSERT, 7, v(7, 3)), NOPR],
            [(GET, 7, v(0)), NOPR, NOPR, NOPR],
        ])

    def test_same_round_insert_get_sees_pre_state(self):
        check_against_oracle([
            [(INSERT, 2, v(2)), (GET, 2, v(0)), NOPR, NOPR],
            [(GET, 2, v(0)), (DELETE, 2, v(0)), NOPR, NOPR],
        ])

    def test_contended_lock_stripe_serializes(self):
        # keys 2 and 4 share lock stripe (2 % 2 == 4 % 2)
        check_against_oracle([
            [(INSERT, 2, v(2)), (INSERT, 4, v(4)),
             (UPDATE, 2, v(2, 5)), (DELETE, 4, v(0))],
            [(GET, 2, v(0)), (GET, 4, v(0)), NOPR, NOPR],
        ])

    def test_capacity_exhaustion_fails_insert(self):
        rounds = []
        # participant 0 inserts S+1 keys mapping to its own slots
        for i in range(S + 1):
            rounds.append([(INSERT, 10 + i, v(10 + i)), NOPR, NOPR, NOPR])
        check_against_oracle(rounds)

    def test_slot_reuse_after_delete(self):
        check_against_oracle([
            [(INSERT, 11, v(11)), NOPR, NOPR, NOPR],
            [(DELETE, 11, v(0)), NOPR, NOPR, NOPR],
            [(INSERT, 13, v(13)), NOPR, NOPR, NOPR],
            [(GET, 11, v(0)), (GET, 13, v(0)), NOPR, NOPR],
        ])


class TestAppendixCValidation:
    """Direct checks of the read-path case analysis (Appendix C)."""

    def _seed_state(self):
        st = kv.init_state()
        op = jnp.asarray([INSERT, NOP, NOP, NOP], jnp.int32)
        key = jnp.asarray([5, 1, 1, 1], jnp.uint32)
        val = jnp.asarray([v(5), (0, 0), (0, 0), (0, 0)], jnp.int32)
        st, _ = step(st, op, key, val)
        return st

    def _get5(self, st):
        op = jnp.asarray([NOP, GET, NOP, NOP], jnp.int32)
        key = jnp.asarray([1, 5, 1, 1], jnp.uint32)
        val = jnp.zeros((P, W), jnp.int32)
        _st, res = step(st, op, key, val)
        return jax.tree.map(np.asarray, res)

    def test_case1_valid_read_returns_value(self):
        res = self._get5(self._seed_state())
        assert res.found[1]
        np.testing.assert_array_equal(res.value[1], v(5))

    def test_case2_torn_row_retries_then_empty(self):
        st = self._seed_state()
        # corrupt the stored row at its host (inserter was participant 0):
        buf = np.asarray(st.rows.buf).copy()
        slot = np.nonzero(buf[0, :, W + 1] == 1)[0][0]  # valid row at node 0
        buf[0, slot, 0] ^= 0x5A5A  # tear the payload, checksum now stale
        st = st._replace(rows=st.rows._replace(buf=jnp.asarray(buf)))
        res = self._get5(st)
        assert not res.found[1]
        assert res.retries[1] == 3  # MAX_GET_RETRIES exhausted

    def test_case3_invalid_bit_returns_empty(self):
        st = self._seed_state()
        buf = np.asarray(st.rows.buf).copy()
        slot = np.nonzero(buf[0, :, W + 1] == 1)[0][0]
        row = buf[0, slot].copy()
        row[W + 1] = 0  # unset valid bit, re-checksum (a mid-insert snapshot)
        from repro.core.ownedvar import checksum as cks
        row[W + 2] = np.asarray(
            jax.lax.bitcast_convert_type(cks(jnp.asarray(row[:W + 2])),
                                         jnp.int32))
        buf[0, slot] = row
        st = st._replace(rows=st.rows._replace(buf=jnp.asarray(buf)))
        res = self._get5(st)
        assert not res.found[1]
        assert res.retries[1] == 0  # clean read, EMPTY by case 3

    def test_case4_counter_mismatch_returns_empty(self):
        st = self._seed_state()
        # stale local index at participant 1: ctr behind the slot's counter
        idx_ctr = np.asarray(st.idx_ctr).copy()
        pos = np.nonzero(np.asarray(st.idx_key)[1] == 5)[0][0]
        idx_ctr[1, pos] -= 1
        st = st._replace(idx_ctr=jnp.asarray(idx_ctr))
        res = self._get5(st)
        assert not res.found[1]
        assert res.retries[1] == 0


class TestKVStoreRandomized:
    @pytest.mark.parametrize("seed", range(6))
    def test_random_batches_match_oracle(self, seed):
        rng = np.random.default_rng(seed)
        keys = list(range(1, 7))
        rounds = []
        for rnd in range(6):
            ops = []
            for p in range(P):
                op = int(rng.choice([NOP, GET, INSERT, UPDATE, DELETE],
                                    p=[.1, .3, .3, .15, .15]))
                key = int(rng.choice(keys))
                ops.append((op, key, v(key, rnd)))
            rounds.append(ops)
        check_against_oracle(rounds)


class TestBatchedGets:
    def test_get_batch_matches_individual_gets(self):
        st = kv.init_state()
        rounds = [[(INSERT, k, v(k)) for k in (1, 2, 3, 4)],
                  [(INSERT, k, v(k)) for k in (5, 6, 1, 2)]]  # 1,2 fail
        for ops in rounds:
            op = jnp.asarray([o[0] for o in ops], jnp.int32)
            key = jnp.asarray([o[1] for o in ops], jnp.uint32)
            val = jnp.asarray([o[2] for o in ops], jnp.int32)
            st, _ = step(st, op, key, val)

        @jax.jit
        def batch_get(st, keys):
            return mgr.runtime.run(
                lambda s, k: kv.get_batch(s, k), st, keys)

        keys = jnp.asarray([[1, 2, 3, 9], [5, 6, 9, 1],
                            [4, 4, 4, 4], [9, 9, 9, 9]], jnp.uint32)
        values, found = batch_get(st, keys)
        values, found = np.asarray(values), np.asarray(found)
        expect_found = np.array([[1, 1, 1, 0], [1, 1, 0, 1],
                                 [1, 1, 1, 1], [0, 0, 0, 0]], bool)
        np.testing.assert_array_equal(found, expect_found)
        np.testing.assert_array_equal(values[0, 0], v(1))
        np.testing.assert_array_equal(values[2, 3], v(4))
