"""ReplicatedLog (DESIGN.md §9.3): the kvstore replication log composed
from Ringbuffer + SST.

Checked here:
* follower state converges **bitwise** to the leader after scripted mixed
  mutation windows (insert/update/delete/get lanes), replayed through the
  kvstore's existing vectorized apply;
* the record-export hook masks non-mutating lanes to NOP and replay of an
  absent (pred=False) entry is the state identity;
* log flow control: appends beyond ring capacity are rejected and counted,
  a sync drains the backlog in order and lag returns to zero;
* multiple followers fed from ONE log drain (single cursor ack) all
  converge.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (DELETE, GET, INSERT, NOP, UPDATE, KVStore,
                        ReplicatedLog, make_manager)
from repro.core.replog import diverging_leaves

P = 4
B = 2
S = 4
W = 2

mgr = make_manager(P)
_kw = dict(slots_per_node=S, value_width=W, num_locks=8, index_capacity=64)
leader = KVStore(None, "rl_leader", mgr, **_kw)
follower = KVStore(None, "rl_follower", mgr, **_kw)
follower2 = KVStore(None, "rl_follower2", mgr, **_kw)
log = ReplicatedLog(None, "rl_log", mgr, store=leader, window=B, capacity=2)


@jax.jit
def lead_append_sync(lst, fst, gst, op, key, val):
    def prog(lst, fst, gst, op, key, val):
        lst, res = leader.op_window(lst, op, key, val)
        gst, ok = log.append(gst, op, key, val)
        gst, fst, applied = log.sync(gst, follower, fst, max_entries=1)
        return lst, fst, gst, res, ok, applied
    return mgr.runtime.run(prog, lst, fst, gst, op, key, val)


@jax.jit
def lead_lockfree_append_sync(lst, fst, gst, op, key, val):
    """Leader serves the window through the §11 lock-free fast path;
    the follower replays the exported records through the locked
    executable spec (``log.sync`` → ``op_window`` default path)."""
    def prog(lst, fst, gst, op, key, val):
        lst, res = leader.op_window(lst, op, key, val, lockfree=True)
        gst, ok = log.append(gst, op, key, val)
        gst, fst, applied = log.sync(gst, follower, fst, max_entries=1)
        return lst, fst, gst, res, ok, applied
    return mgr.runtime.run(prog, lst, fst, gst, op, key, val)


@jax.jit
def append_only(lst, gst, op, key, val):
    def prog(lst, gst, op, key, val):
        lst, _res = leader.op_window(lst, op, key, val)
        gst, ok = log.append(gst, op, key, val)
        return lst, gst, ok
    return mgr.runtime.run(prog, lst, gst, op, key, val)


@jax.jit
def append_retry(gst, op, key, val):
    """Publish-only retry: the leader already committed the window."""
    def prog(gst, op, key, val):
        return log.append(gst, op, key, val)
    return mgr.runtime.run(prog, gst, op, key, val)


@jax.jit
def sync_many(gst, fst, n=2):
    def prog(gst, fst):
        gst, fst, applied = log.sync(gst, follower, fst, max_entries=2)
        return gst, fst, applied
    return mgr.runtime.run(prog, gst, fst)


@jax.jit
def sync_one(gst, fst):
    def prog(gst, fst):
        gst, fst, applied = log.sync(gst, follower, fst, max_entries=1)
        return gst, fst, applied
    return mgr.runtime.run(prog, gst, fst)


def states():
    return leader.init_state(), follower.init_state(), log.init_state()


def window(*lanes):
    """lanes: P lists of B (op, key, (v0, v1)) tuples → jnp arrays."""
    op = jnp.asarray([[o[0] for o in ln] for ln in lanes], jnp.int32)
    key = jnp.asarray([[o[1] for o in ln] for ln in lanes], jnp.uint32)
    val = jnp.asarray([[o[2] for o in ln] for ln in lanes], jnp.int32)
    return op, key, val


def assert_converged(lst, fst):
    """Bitwise leaf-by-leaf equality of leader and follower states (the
    shared §9.3 check; the read cache is local policy, not replicated
    data and is skipped there)."""
    diverged = diverging_leaves(lst, fst)
    assert not diverged, f"leader/follower diverged on leaves {diverged}"


NL = (NOP, 1, (0, 0))


class TestReplicatedLog:
    def test_follower_bitwise_converges_on_mixed_windows(self):
        lst, fst, gst = states()
        rounds = [
            window([(INSERT, 1, (10, 11)), (INSERT, 5, (50, 51))],
                   [(INSERT, 2, (20, 21)), NL],
                   [NL, (INSERT, 3, (30, 31))],
                   [(INSERT, 4, (40, 41)), NL]),
            window([(UPDATE, 1, (12, 13)), (GET, 2, (0, 0))],
                   [(DELETE, 5, (0, 0)), NL],
                   [(GET, 3, (0, 0)), (UPDATE, 3, (32, 33))],
                   [NL, (DELETE, 4, (0, 0))]),
            window([(INSERT, 6, (60, 61)), (DELETE, 1, (0, 0))],
                   [(UPDATE, 2, (22, 23)), (INSERT, 7, (70, 71))],
                   [NL, NL],
                   [(GET, 6, (0, 0)), (UPDATE, 6, (62, 63))]),
        ]
        for op, key, val in rounds:
            lst, fst, gst, _res, ok, applied = lead_append_sync(
                lst, fst, gst, op, key, val)
            assert np.all(np.asarray(ok)), "append must land (ring sized)"
            np.testing.assert_array_equal(np.asarray(applied), [1] * P)
            assert_converged(lst, fst)
        lag = np.asarray(mgr.runtime.run(log.lag, gst))
        np.testing.assert_array_equal(lag, [0] * P)

    def test_lockfree_window_replays_bitwise_through_locked_spec(self):
        """§11 replication invariant: a leader that serves a commuting
        (all-UPDATE) window through the lock-free fast path exports the
        same records and commits the same state bits as the locked spec
        — so a follower replaying through the LOCKED path converges
        bitwise on every leaf, lock counters included (``locks`` is not
        in the diverging_leaves skip-list)."""
        lst, fst, gst = states()
        seed = window([(INSERT, 1, (10, 11)), (INSERT, 5, (50, 51))],
                      [(INSERT, 2, (20, 21)), NL],
                      [NL, (INSERT, 3, (30, 31))],
                      [(INSERT, 4, (40, 41)), NL])
        # mixed window through the lock-free step → falls back to the
        # locked schedule (win_fast=False), still bit-identical
        lst, fst, gst, res, ok, _applied = lead_lockfree_append_sync(
            lst, fst, gst, *seed)
        assert np.all(np.asarray(ok))
        assert_converged(lst, fst)
        rounds = [
            # commuting fast window: all lock-wanting lanes UPDATE,
            # including a cross-participant same-key pair (keys 1, 3)
            window([(UPDATE, 1, (12, 13)), (UPDATE, 5, (52, 53))],
                   [(UPDATE, 2, (22, 23)), (GET, 1, (0, 0))],
                   [(GET, 3, (0, 0)), (UPDATE, 3, (32, 33))],
                   [(UPDATE, 1, (14, 15)), NL]),
            # pure-GET window: vacuously fast, zero mutations to replay
            window([(GET, 1, (0, 0)), (GET, 5, (0, 0))],
                   [(GET, 2, (0, 0)), NL],
                   [(GET, 3, (0, 0)), (GET, 4, (0, 0))],
                   [NL, (GET, 1, (0, 0))]),
        ]
        for op, key, val in rounds:
            lst, fst, gst, res, ok, _applied = lead_lockfree_append_sync(
                lst, fst, gst, op, key, val)
            assert np.all(np.asarray(ok)), "append must land (ring sized)"
            assert_converged(lst, fst)
        # the same-key UPDATE race resolved last-(participant, lane)-wins
        # on BOTH sides: the replayed follower serves the winning value
        got = np.asarray(res.value)
        np.testing.assert_array_equal(got[3, 1], [14, 15])
        lag = np.asarray(mgr.runtime.run(log.lag, gst))
        np.testing.assert_array_equal(lag, [0] * P)

    def test_export_masks_non_mutations_and_replay_identity(self):
        op, key, val = window(
            [(GET, 1, (1, 1)), (INSERT, 2, (2, 2))],
            [(NOP, 3, (3, 3)), (UPDATE, 4, (4, 4))],
            [(DELETE, 5, (5, 5)), (GET, 6, (6, 6))],
            [NL, NL])

        @jax.jit
        def export(op, key, val):
            return mgr.runtime.run(leader.export_window_records, op, key,
                                   val)

        recs = np.asarray(export(op, key, val))          # (P, B, 5)
        assert recs.shape == (P, B, leader.record_width)
        np.testing.assert_array_equal(
            recs[..., 0], [[NOP, INSERT], [NOP, UPDATE],
                           [DELETE, NOP], [NOP, NOP]])
        # value words ride along; the trailing word is the lane's
        # RESOLVED home — the writer itself on this writer-local store
        # (§10: replay is policy-independent because the record carries
        # the decision, not the hint)
        np.testing.assert_array_equal(recs[0, 1, 2:4], [2, 2])
        np.testing.assert_array_equal(
            recs[..., 4], np.broadcast_to(np.arange(P)[:, None], (P, B)))

        # replay with pred=False is the state identity
        lst = leader.init_state()

        @jax.jit
        def replay_masked(lst, recs):
            def prog(lst, recs):
                lst, _res = leader.replay_window_records(
                    lst, recs, pred=False)
                return lst
            return mgr.runtime.run(prog, lst, recs)

        lst2 = replay_masked(lst, jnp.asarray(recs))
        for la, lb in zip(jax.tree.leaves(lst), jax.tree.leaves(lst2)):
            np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))

    def test_flow_control_counts_drops_and_backlog_drains_in_order(self):
        lst, fst, gst = states()
        wins = [window([(INSERT, k, (int(k), int(k))), NL],
                       [NL, NL], [NL, NL], [NL, NL]) for k in (1, 2, 3)]
        # capacity 2: two appends fill the ring, the third drops (the
        # leader's op still committed locally — replication falls behind,
        # never forks)
        for i, (op, key, val) in enumerate(wins):
            lst, gst, ok = append_only(lst, gst, op, key, val)
            assert bool(np.asarray(ok)[0]) == (i < 2)
        pub = np.asarray(gst.published)[0]
        drop = np.asarray(gst.dropped)[0]
        assert (pub, drop) == (2, 1)
        lag = np.asarray(mgr.runtime.run(log.lag, gst))[0]
        assert lag == 2
        # one sync drains the whole backlog, in log order
        gst, fst, applied = sync_many(gst, fst)
        np.testing.assert_array_equal(np.asarray(applied), [2] * P)
        assert np.asarray(mgr.runtime.run(log.lag, gst))[0] == 0
        # the caller's retry protocol: re-APPEND the dropped window
        # (publish-only — the leader already committed it) and sync
        gst, ok = append_retry(gst, *wins[2])
        assert np.all(np.asarray(ok)), "append retry lands after the drain"
        gst, fst, applied = sync_many(gst, fst)
        np.testing.assert_array_equal(np.asarray(applied), [1] * P)
        assert_converged(lst, fst)

    def test_partial_sync_lag_counts_down_and_converges_late(self):
        """§12 satellite: ``lag()`` telemetry under partial sync — a
        follower that drained only k of the n acked entries reports lag
        n−k, is *detectably* diverged from the leader (the progress gap
        is real state, not just a counter), and converges bitwise once
        the remaining entries drain."""
        lst, fst, gst = states()
        wins = [window([(INSERT, k, (int(k) * 7, int(k))), NL],
                       [NL, (UPDATE, 1, (9, 9)) if k == 2 else NL],
                       [NL, NL], [NL, NL]) for k in (1, 2)]
        for op, key, val in wins:                # n = 2 acked entries
            lst, gst, ok = append_only(lst, gst, op, key, val)
            assert bool(np.asarray(ok)[0])
        assert np.asarray(mgr.runtime.run(log.lag, gst))[0] == 2
        gst, fst, applied = sync_one(gst, fst)   # k = 1 of n = 2
        np.testing.assert_array_equal(np.asarray(applied), [1] * P)
        assert np.asarray(mgr.runtime.run(log.lag, gst))[0] == 1
        assert diverging_leaves(jax.tree.map(np.asarray, lst),
                                jax.tree.map(np.asarray, fst)), \
            "one undrained entry must leave a detectable divergence"
        gst, fst, applied = sync_one(gst, fst)   # the remaining entry
        np.testing.assert_array_equal(np.asarray(applied), [1] * P)
        assert np.asarray(mgr.runtime.run(log.lag, gst))[0] == 0
        assert_converged(lst, fst)

    def test_multiple_followers_one_drain(self):
        lst = leader.init_state()
        f1, f2 = follower.init_state(), follower2.init_state()
        gst = log.init_state()

        @jax.jit
        def step(lst, f1, f2, gst, op, key, val):
            def prog(lst, f1, f2, gst, op, key, val):
                lst, _res = leader.op_window(lst, op, key, val)
                gst, ok = log.append(gst, op, key, val)
                gst, (f1, f2), applied = log.sync(
                    gst, [follower, follower2], (f1, f2), max_entries=1)
                return lst, f1, f2, gst, ok, applied
            return mgr.runtime.run(prog, lst, f1, f2, gst, op, key, val)

        rounds = [
            window([(INSERT, 1, (1, 2)), (INSERT, 2, (3, 4))],
                   [(INSERT, 8, (5, 6)), NL], [NL, NL], [NL, NL]),
            window([(UPDATE, 1, (7, 8)), (DELETE, 2, (0, 0))],
                   [NL, (UPDATE, 8, (9, 9))], [NL, NL], [NL, NL]),
        ]
        for op, key, val in rounds:
            lst, f1, f2, gst, ok, applied = step(
                lst, f1, f2, gst, op, key, val)
            assert np.all(np.asarray(ok))
        assert_converged(lst, f1)
        assert_converged(lst, f2)
