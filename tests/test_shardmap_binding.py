"""The production binding: identical channel code under jax.shard_map over a
real device mesh.  Run in a subprocess so the 8 fake host devices don't leak
into other tests' device state."""
import os
import subprocess
import sys
import textwrap

import pytest

PROG = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from repro.core import (Barrier, KVStore, SharedQueue, make_manager,
                            INSERT, GET, NOP)

    P = 8
    if hasattr(jax.sharding, "AxisType"):          # jax >= 0.5
        mesh = jax.make_mesh((P,), ("nodes",),
                             axis_types=(jax.sharding.AxisType.Auto,))
    else:
        mesh = jax.make_mesh((P,), ("nodes",))
    mgr = make_manager(P, axis="nodes", mesh=mesh)

    # --- barrier under shard_map
    bar = Barrier(None, "bar", mgr)
    st = bar.init_state()
    def prog(s):
        s = bar.wait(s)
        return bar.wait(s)
    st = jax.jit(lambda s: mgr.runtime.run(prog, s))(st)
    assert np.all(np.asarray(st.count) == 2), st.count

    # --- kvstore round-trip under shard_map
    kv = KVStore(None, "kv", mgr, slots_per_node=2, value_width=2,
                 num_locks=4, index_capacity=64)
    kst = kv.init_state()
    step = jax.jit(lambda s, o, k, v: mgr.runtime.run(kv.op_round, s, o, k, v))
    ops = jnp.asarray([INSERT] * P, jnp.int32)
    keys = jnp.arange(1, P + 1, dtype=jnp.uint32)
    vals = jnp.stack([jnp.arange(1, P + 1), jnp.arange(1, P + 1) * 7],
                     axis=1).astype(jnp.int32)
    kst, res = step(kst, ops, keys, vals)
    assert np.all(np.asarray(res.found)), res.found
    gets = jnp.asarray([GET] * P, jnp.int32)
    gkeys = jnp.asarray(list(reversed(range(1, P + 1))), jnp.uint32)
    kst, res = step(kst, gets, gkeys, jnp.zeros((P, 2), jnp.int32))
    assert np.all(np.asarray(res.found))
    want = np.stack([np.asarray(gkeys), np.asarray(gkeys) * 7], axis=1)
    np.testing.assert_array_equal(np.asarray(res.value), want)

    # --- queue under shard_map
    q = SharedQueue(None, "q", mgr, slots_per_node=2, width=1)
    qst = q.init_state()
    def qprog(s, v):
        s, _ = q.enqueue(s, v)
        return q.dequeue(s)
    qst, vals_out, ok = jax.jit(
        lambda s, v: mgr.runtime.run(qprog, s, v))(
        qst, jnp.arange(P, dtype=jnp.int32)[:, None])
    assert np.all(np.asarray(ok))
    np.testing.assert_array_equal(np.asarray(vals_out)[:, 0], np.arange(P))
    print("SHARD_MAP_BINDING_OK")
""")


PROG2 = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from repro.core import GET, INSERT, KVStore, make_manager
    from repro.core.kvstore import IDX_NODE, IDX_STATE, _USED

    P, B, W = 8, 2, 2
    if hasattr(jax.sharding, "AxisType"):          # jax >= 0.5
        mesh = jax.make_mesh((P,), ("nodes",),
                             axis_types=(jax.sharding.AxisType.Auto,))
    else:
        mesh = jax.make_mesh((P,), ("nodes",))
    mgr = make_manager(P, axis="nodes", mesh=mesh)

    kv = KVStore(None, "kv", mgr, slots_per_node=4, value_width=W,
                 num_locks=8, index_capacity=128, placement="explicit")
    st = kv.init_state()
    step = jax.jit(lambda s, o, k, v, t: mgr.runtime.run(
        lambda s_, o_, k_, v_, t_: kv.op_window(s_, o_, k_, v_, targets=t_),
        s, o, k, v, t))
    move = jax.jit(lambda s, k, d, p: mgr.runtime.run(
        lambda s_, k_, d_, p_: kv.migrate_window(s_, k_, d_, preds=p_),
        s, k, d, p))

    def homes(state):
        idx = np.asarray(state.idx[0])
        used = idx[:, IDX_STATE] == _USED
        return {int(np.uint32(r[1])): int(r[IDX_NODE]) for r in idx[used]}

    # --- explicit placement: participant p INSERTs keys (2p+1, 2p+2),
    # homed at key % P — a REMOTE home for most writers.
    keys = np.arange(1, 2 * P + 1, dtype=np.uint32).reshape(P, B)
    vals = jnp.stack([jnp.asarray(keys, jnp.int32) * 10,
                      jnp.asarray(keys, jnp.int32) * 100], axis=-1)
    st, res = step(st, jnp.full((P, B), INSERT, jnp.int32),
                   jnp.asarray(keys), vals, jnp.asarray(keys % P, jnp.int32))
    assert np.all(np.asarray(res.found)), res.found
    assert homes(st) == {int(k): int(k) % P for k in keys.ravel()}, homes(st)

    # --- MOVE under shard_map: re-home every key to (key + 3) % P; one
    # absent-key lane and one pred-masked lane must fail cleanly.
    mkeys = keys.copy(); mkeys[0, 1] = 999         # absent key
    preds = np.ones((P, B), bool); preds[1, 0] = False
    st, moved = move(st, jnp.asarray(mkeys),
                     jnp.asarray((keys + 3) % P, jnp.int32),
                     jnp.asarray(preds))
    moved = np.asarray(moved)
    assert not moved[0, 1] and not moved[1, 0], moved
    assert moved.sum() == P * B - 2, moved
    want = {int(k): (int(k) + 3) % P for k in keys.ravel()}
    want[int(keys[0, 1])] = int(keys[0, 1]) % P    # lane carried 999 instead
    want[int(keys[1, 0])] = int(keys[1, 0]) % P    # pred-masked
    assert homes(st) == want, (homes(st), want)

    # --- values survive the re-home: shifted readers GET every key
    gkeys = np.roll(keys.ravel(), 3).reshape(P, B)
    st, res = step(st, jnp.full((P, B), GET, jnp.int32), jnp.asarray(gkeys),
                   jnp.zeros((P, B, W), jnp.int32),
                   jnp.zeros((P, B), jnp.int32))
    assert np.all(np.asarray(res.found))
    np.testing.assert_array_equal(
        np.asarray(res.value),
        np.stack([gkeys * 10, gkeys * 100], axis=-1).astype(np.int32))
    print("SHARD_MAP_MOVE_OK")
""")


def test_channels_under_shardmap_mesh():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src"))
    r = subprocess.run([sys.executable, "-c", PROG], capture_output=True,
                       text=True, env=env, timeout=600)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    assert "SHARD_MAP_BINDING_OK" in r.stdout


def test_move_and_explicit_placement_under_shardmap_mesh():
    """§10 on the production binding: explicit-placement INSERT windows
    and MOVE migration re-home rows correctly on a real 8-device mesh
    axis, not just under the vmap emulation."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src"))
    r = subprocess.run([sys.executable, "-c", PROG2], capture_output=True,
                       text=True, env=env, timeout=600)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    assert "SHARD_MAP_MOVE_OK" in r.stdout
