"""The production binding: identical channel code under jax.shard_map over a
real device mesh.  Run in a subprocess so the 8 fake host devices don't leak
into other tests' device state."""
import os
import subprocess
import sys
import textwrap

import pytest

PROG = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from repro.core import (Barrier, KVStore, SharedQueue, make_manager,
                            INSERT, GET, NOP)

    P = 8
    if hasattr(jax.sharding, "AxisType"):          # jax >= 0.5
        mesh = jax.make_mesh((P,), ("nodes",),
                             axis_types=(jax.sharding.AxisType.Auto,))
    else:
        mesh = jax.make_mesh((P,), ("nodes",))
    mgr = make_manager(P, axis="nodes", mesh=mesh)

    # --- barrier under shard_map
    bar = Barrier(None, "bar", mgr)
    st = bar.init_state()
    def prog(s):
        s = bar.wait(s)
        return bar.wait(s)
    st = jax.jit(lambda s: mgr.runtime.run(prog, s))(st)
    assert np.all(np.asarray(st.count) == 2), st.count

    # --- kvstore round-trip under shard_map
    kv = KVStore(None, "kv", mgr, slots_per_node=2, value_width=2,
                 num_locks=4, index_capacity=64)
    kst = kv.init_state()
    step = jax.jit(lambda s, o, k, v: mgr.runtime.run(kv.op_round, s, o, k, v))
    ops = jnp.asarray([INSERT] * P, jnp.int32)
    keys = jnp.arange(1, P + 1, dtype=jnp.uint32)
    vals = jnp.stack([jnp.arange(1, P + 1), jnp.arange(1, P + 1) * 7],
                     axis=1).astype(jnp.int32)
    kst, res = step(kst, ops, keys, vals)
    assert np.all(np.asarray(res.found)), res.found
    gets = jnp.asarray([GET] * P, jnp.int32)
    gkeys = jnp.asarray(list(reversed(range(1, P + 1))), jnp.uint32)
    kst, res = step(kst, gets, gkeys, jnp.zeros((P, 2), jnp.int32))
    assert np.all(np.asarray(res.found))
    want = np.stack([np.asarray(gkeys), np.asarray(gkeys) * 7], axis=1)
    np.testing.assert_array_equal(np.asarray(res.value), want)

    # --- queue under shard_map
    q = SharedQueue(None, "q", mgr, slots_per_node=2, width=1)
    qst = q.init_state()
    def qprog(s, v):
        s, _ = q.enqueue(s, v)
        return q.dequeue(s)
    qst, vals_out, ok = jax.jit(
        lambda s, v: mgr.runtime.run(qprog, s, v))(
        qst, jnp.arange(P, dtype=jnp.int32)[:, None])
    assert np.all(np.asarray(ok))
    np.testing.assert_array_equal(np.asarray(vals_out)[:, 0], np.arange(P))
    print("SHARD_MAP_BINDING_OK")
""")


def test_channels_under_shardmap_mesh():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src"))
    r = subprocess.run([sys.executable, "-c", PROG], capture_output=True,
                       text=True, env=env, timeout=600)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    assert "SHARD_MAP_BINDING_OK" in r.stdout
