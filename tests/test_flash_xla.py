"""flash_attention_xla (compile substrate): forward AND gradients vs the
naive oracle, across masks/GQA/offsets."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.models.flash_xla import flash_attention_xla

jax.config.update("jax_default_matmul_precision", "highest")


def rand(shape, rng, scale=1.0):
    return jnp.asarray(rng.standard_normal(shape) * scale, jnp.float32)


@pytest.mark.parametrize(
    "B,Hq,Hkv,Sq,Sk,D,causal,window",
    [(1, 4, 4, 64, 64, 32, True, None),
     (2, 8, 2, 128, 128, 32, True, None),
     (1, 2, 2, 64, 192, 32, True, None),        # decode offset
     (1, 2, 2, 128, 128, 32, True, 32),         # sliding window
     (1, 2, 2, 96, 96, 32, False, None)])       # bidirectional
def test_flash_xla_forward_and_grads_match_naive(B, Hq, Hkv, Sq, Sk, D,
                                                 causal, window):
    rng = np.random.default_rng(0)
    q, k, v = (rand((B, Hq, Sq, D), rng),
               rand((B, Hkv, Sk, D), rng),
               rand((B, Hkv, Sk, D), rng))

    def loss_flash(q, k, v):
        o = flash_attention_xla(q, k, v, causal, window, None, None, 48)
        return jnp.sum(jnp.sin(o.astype(jnp.float32)))

    def loss_naive(q, k, v):
        o = ref.mha(q, k, v, causal=causal, window=window)
        return jnp.sum(jnp.sin(o.astype(jnp.float32)))

    lf, gf = jax.value_and_grad(loss_flash, argnums=(0, 1, 2))(q, k, v)
    ln, gn = jax.value_and_grad(loss_naive, argnums=(0, 1, 2))(q, k, v)
    np.testing.assert_allclose(float(lf), float(ln), rtol=1e-5)
    for a, b, name in zip(gf, gn, "qkv"):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=2e-4, rtol=2e-4,
                                   err_msg=f"grad d{name}")


def test_flash_xla_distinct_dv():
    """MLA uses Dk != Dv."""
    rng = np.random.default_rng(1)
    q = rand((1, 4, 64, 48), rng)
    k = rand((1, 4, 64, 48), rng)
    v = rand((1, 4, 64, 32), rng)
    o = flash_attention_xla(q, k, v, True, None, None, None, 32)
    want = ref.mha(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(o), np.asarray(want),
                               atol=1e-5, rtol=1e-5)
