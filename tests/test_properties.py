"""Property-based tests (hypothesis) for channel invariants.

Invariants checked:
* kvstore linearizability: random op batches match the sequential oracle
  over the induced linearization order (Appendix C) — single-op rounds AND
  windowed histories (op_window: GETs at window start, mutations in
  participant-then-window order).
* row encoding: the checksum catches any single-word tear; the Appendix C
  counter/valid case analysis holds elementwise over batched rows.
* shared queue: FIFO, no loss, no duplication, pop≤push.
* atomic_var FAA: tickets are a permutation (mutual exclusion of tickets).
* checksum: detects any single-lane corruption; deterministic.

Requires ``hypothesis`` (requirements-dev.txt); skips cleanly without it.
"""
import pytest

pytest.importorskip(
    "hypothesis",
    reason="property tests need hypothesis (pip install -r "
           "requirements-dev.txt); deterministic mirrors of the kvstore/row "
           "properties run in test_kvstore.py")

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core import (DELETE, GET, INSERT, NOP, UPDATE, AtomicVar,
                        SharedQueue, make_manager)
from repro.core.ownedvar import checksum

import test_kvstore as kvmod

P = 4

# ----------------------------------------------------------- kvstore lineariz.
op_strategy = st.tuples(
    st.sampled_from([NOP, GET, INSERT, UPDATE, DELETE]),
    st.integers(min_value=1, max_value=6))


@settings(max_examples=25, deadline=None)
@given(st.lists(st.lists(op_strategy, min_size=P, max_size=P),
                min_size=1, max_size=5))
def test_kvstore_linearizable_against_oracle(batches):
    rounds = []
    for rnd, ops in enumerate(batches):
        rounds.append([(op, key, kvmod.v(key, rnd)) for op, key in ops])
    kvmod.check_against_oracle(rounds)


# ------------------------------------------------- windowed kvstore lineariz.
@settings(max_examples=15, deadline=None)
@given(st.lists(
    st.lists(st.lists(op_strategy, min_size=2, max_size=2),
             min_size=P, max_size=P),
    min_size=1, max_size=3))
def test_kvstore_windows_linearizable_against_oracle(batches):
    """Random (P, B=2) windows replay against the oracle in the
    window-induced total order (GETs at window start; mutations in
    participant-then-window order)."""
    windows = []
    for rnd, lanes in enumerate(batches):
        windows.append([[(op, key, kvmod.v(key, rnd * 2 + b))
                         for b, (op, key) in enumerate(lane)]
                        for lane in lanes])
    kvmod.check_windows_against_oracle(windows)


# ------------------------------------------------- read-tier properties (§8)
@settings(max_examples=12, deadline=None)
@given(st.lists(
    st.lists(st.lists(op_strategy, min_size=2, max_size=2),
             min_size=P, max_size=P),
    min_size=1, max_size=4))
def test_cached_windows_never_return_stale_values(batches):
    """Random interleavings of writes and cached reads on a cache-enabled
    store match the sequential oracle — a GET served from the cache is
    indistinguishable from one served over the wire, under every
    insert/update/delete/reuse interleaving hypothesis finds."""
    windows = []
    for rnd, lanes in enumerate(batches):
        windows.append([[(op, key, kvmod.v(key, rnd * 2 + b))
                         for b, (op, key) in enumerate(lane)]
                        for lane in lanes])
    kvmod.check_windows_against_oracle(windows, store_mgr=kvmod.cmgr,
                                       store=kvmod.ckv)


@settings(max_examples=12, deadline=None)
@given(st.lists(
    st.lists(st.lists(op_strategy, min_size=2, max_size=2),
             min_size=P, max_size=P),
    min_size=1, max_size=4))
def test_cached_get_window_bitwise_equals_reference(batches):
    """After every window of a random mutation history, the cached read
    path and ``_get_window_reference`` return bit-identical (values,
    found) on the same state (the §8.2 validation protocol never serves a
    row the wire would not)."""
    import jax.numpy as jnp
    state = kvmod.ckv.init_state()
    probe = jnp.broadcast_to(jnp.arange(1, 9, dtype=jnp.uint32), (P, 8))
    for rnd, lanes in enumerate(batches):
        op = jnp.asarray([[o for o, _k in lane] for lane in lanes],
                         jnp.int32)
        key = jnp.asarray([[k for _o, k in lane] for lane in lanes],
                          jnp.uint32)
        val = jnp.asarray([[kvmod.v(k, rnd * 2 + b)
                            for b, (_o, k) in enumerate(lane)]
                           for lane in lanes], jnp.int32)
        state, _res = kvmod.cached_window_step(state, op, key, val)
        (cv, cf), (rv, rf) = kvmod.cached_vs_reference_reads(state, probe)
        np.testing.assert_array_equal(np.asarray(cf), np.asarray(rf))
        np.testing.assert_array_equal(np.asarray(cv), np.asarray(rv))


# ------------------------------------------------------------- row encoding
word = st.integers(min_value=-2**31, max_value=2**31 - 1)


@settings(max_examples=40, deadline=None)
@given(st.lists(word, min_size=kvmod.W, max_size=kvmod.W),
       st.integers(min_value=0, max_value=2**32 - 1),
       st.booleans(),
       st.integers(min_value=0, max_value=kvmod.W + 1),
       st.integers(min_value=1, max_value=2**31 - 1))
def test_encode_row_checksum_catches_single_word_tear(payload, ctr, valid,
                                                      pos, delta):
    kv = kvmod.kv
    row = kv.encode_row(jnp.asarray(payload, jnp.int32),
                        jnp.uint32(ctr), valid)
    _p, _c, _v, ok = kv.decode_row(row)
    assert bool(ok)
    torn = row.at[pos].set(row[pos] ^ jnp.int32(delta))
    if bool(jnp.all(torn == row)):
        return              # delta was a no-op on this word
    _p, _c, _v, ok = kv.decode_row(torn)
    assert not bool(ok), f"tear at word {pos} must break the checksum"


@settings(max_examples=25, deadline=None)
@given(st.lists(st.tuples(
    st.lists(word, min_size=kvmod.W, max_size=kvmod.W),
    st.integers(min_value=1, max_value=2**32 - 1),
    st.booleans(), st.booleans()),
    min_size=1, max_size=6))
def test_decode_row_case_analysis_elementwise(rows_spec):
    """Appendix C counter/valid cases over a batched row set: a row is
    accepted iff clean, valid and counter-current — checked elementwise
    under vmap exactly as the batched read path applies it."""
    kv = kvmod.kv
    rows, expect = [], []
    for payload, ctr, valid, stale in rows_spec:
        rows.append(kv.encode_row(jnp.asarray(payload, jnp.int32),
                                  jnp.uint32(ctr), valid))
        # the index advertises ctr; a stale replica advertises ctr-1
        expect.append(valid and not stale)
    batch = jnp.stack(rows)
    payloads, ctrs, valids, oks = jax.vmap(kv.decode_row)(batch)
    idx_ctr = jnp.asarray(
        [c - 1 if stale else c for (_p, c, _v, stale) in rows_spec],
        jnp.uint32)
    accept = np.asarray(oks) & np.asarray(valids) & \
        (np.asarray(ctrs) == np.asarray(idx_ctr))
    np.testing.assert_array_equal(accept, np.asarray(expect, bool))
    for i, (payload, _c, _v, _s) in enumerate(rows_spec):
        np.testing.assert_array_equal(np.asarray(payloads)[i],
                                      np.asarray(payload, np.int32))


# ------------------------------------------------- hash-index invariants
# one harness per configuration, shared across examples (state is rebuilt
# per example; the jitted apply/lookup callables are what we reuse)
_H8 = kvmod._ApplyHarness(C=8, S=32)
_HV16 = kvmod._ApplyHarness(C=16, S=32)
_HS16 = kvmod._ApplyHarness(C=16, S=32)


@settings(max_examples=20, deadline=None)
@given(st.lists(st.tuples(st.booleans(),
                          st.integers(min_value=1, max_value=6)),
                min_size=1, max_size=14),
       st.integers(min_value=0, max_value=3))
def test_hash_index_lookup_pinned_to_reference_scan(chain, seed):
    """After any protocol-valid tracker stream (same-key records alternate
    insert/delete), the O(PROBE) hash probe is bit-for-bit equal to the
    O(C) reference scan on the same state — found, pos, node, slot and ctr,
    across collision chains, wraparound and tombstones (C=8 forces all
    three)."""
    h = _H8
    live, entries, ctr = {}, [], 0
    for want_ins, key in chain:
        if live.get(key):
            entries.append((2, key) + live[key])
            live[key] = None
        elif want_ins:
            ctr += 1
            loc = ((key + seed) % P, ctr % 16, ctr)
            entries.append((1, key) + loc)
            live[key] = loc
    if not entries:
        return
    state, applied = h.apply(h.init(), kvmod._recs(*entries))
    probe_keys = list(range(1, 12))
    a = h.lookup(state, probe_keys, "hash")
    b = h.lookup(state, probe_keys, "ref")
    for la, lb in zip(a, b):
        np.testing.assert_array_equal(la, lb)
    # every live key is reachable; dead/absent keys are not (keys are
    # capped at 6 < C so no insert can overflow the window)
    found = dict(zip(probe_keys, np.asarray(a[0], bool)))
    for key in range(1, 7):
        assert found[key] == bool(live.get(key))


@settings(max_examples=20, deadline=None)
@given(st.lists(st.tuples(st.booleans(),
                          st.integers(min_value=1, max_value=6)),
                min_size=1, max_size=12))
def test_tracker_apply_vectorized_equals_sequential(chain):
    """The wave-scheduled tracker apply is logically equivalent to the
    sequential reference sweep on adversarial same-key chains: identical
    applied flags, per-key logical lookups, free-stack effects and
    overflow latch."""
    live, entries, ctr = {}, [], 0
    for want_ins, key in chain:
        if live.get(key):
            entries.append((2, key) + live[key])
            live[key] = None
        elif want_ins:
            ctr += 1
            loc = (key % P, ctr % 16, ctr)
            entries.append((1, key) + loc)
            live[key] = loc
    if not entries:
        return
    kvmod.TestTrackerApplyEquivalence()._check(
        kvmod._recs(*entries), hv=_HV16, hs=_HS16)


# ----------------------------------------------------------------- queue FIFO
qmgr = make_manager(P)
q = SharedQueue(None, "pq", qmgr, slots_per_node=3, width=1)


@jax.jit
def q_step(st, enq_want, enq_val, deq_want):
    def prog(st, ew, ev, dw):
        st, eok = q.enqueue(st, ev, want=ew)
        st, val, dok = q.dequeue(st, want=dw)
        return st, eok, val, dok
    return qmgr.runtime.run(prog, st, enq_want, enq_val, deq_want)


@settings(max_examples=25, deadline=None)
@given(st.lists(st.tuples(
    st.lists(st.booleans(), min_size=P, max_size=P),
    st.lists(st.booleans(), min_size=P, max_size=P)),
    min_size=1, max_size=5))
def test_queue_fifo_no_loss_no_dup(rounds):
    state = q.init_state()
    pushed, popped = [], []
    counter = 0
    for enq_wants, deq_wants in rounds:
        vals = []
        for w in enq_wants:
            vals.append(counter if w else -1)
            counter += 1
        state, eok, dval, dok = q_step(
            state,
            jnp.asarray(enq_wants), jnp.asarray(vals, jnp.int32)[:, None],
            jnp.asarray(deq_wants))
        eok, dval, dok = (np.asarray(eok), np.asarray(dval), np.asarray(dok))
        # enqueue grants in participant order
        for p in range(P):
            if eok[p]:
                pushed.append(vals[p])
        for p in range(P):
            if dok[p]:
                popped.append(int(dval[p, 0]))
    # FIFO w.r.t. grant order: popped must be a prefix-sequence of pushed
    assert popped == pushed[:len(popped)]
    assert len(set(popped)) == len(popped)          # no duplication
    assert len(popped) <= len(pushed)               # pop ≤ push


# ------------------------------------------------------------------ FAA tickets
amgr = make_manager(P)
av = AtomicVar(None, "pa", amgr, host=0, dtype=jnp.int32)


@jax.jit
def faa_step(st, want):
    def prog(st, w):
        st, old, _ = av.fetch_add(st, 1, pred=w)
        return st, old
    return amgr.runtime.run(prog, st, want)


@settings(max_examples=30, deadline=None)
@given(st.lists(st.lists(st.booleans(), min_size=P, max_size=P),
                min_size=1, max_size=6))
def test_faa_tickets_form_permutation(rounds):
    state = av.init_state(0)
    tickets = []
    for wants in rounds:
        state, old = faa_step(state, jnp.asarray(wants))
        old = np.asarray(old)
        for p in range(P):
            if wants[p]:
                tickets.append(int(old[p]))
    assert sorted(tickets) == list(range(len(tickets)))


# ------------------------------------------------------------------- checksum
@settings(max_examples=40, deadline=None)
@given(st.lists(st.integers(min_value=-2**31, max_value=2**31 - 1),
                min_size=1, max_size=16),
       st.integers(min_value=0, max_value=15),
       st.integers(min_value=1, max_value=2**31 - 1))
def test_checksum_detects_single_lane_corruption(words, pos, delta):
    x = jnp.asarray(words, jnp.int32)
    c1 = checksum(x)
    y = x.at[pos % len(words)].add(jnp.int32(delta))
    c2 = checksum(y)
    if bool(jnp.all(x == y)):  # delta wrapped to zero — no corruption
        assert int(c1) == int(c2)
    else:
        assert int(c1) != int(c2)
    # determinism
    assert int(checksum(x)) == int(c1)
