"""Property-based tests (hypothesis) for channel invariants.

Invariants checked:
* kvstore linearizability: random op batches match the sequential oracle
  over the induced linearization order (Appendix C) — single-op rounds AND
  windowed histories (op_window: GETs at window start, mutations in
  participant-then-window order).
* row encoding: the checksum catches any single-word tear; the Appendix C
  counter/valid case analysis holds elementwise over batched rows.
* shared queue: FIFO, no loss, no duplication, pop≤push — scalar rounds
  AND windowed rounds (enqueue_window/dequeue_window) under random
  (P, B, capacity) configurations, against the lex-order FIFO oracle.
* ringbuffer: fuzzed payload/seq/len/csum corruption of a consumer's
  cached slots must never yield a checksum-valid *wrong* message — every
  delivered message is exactly the published one at that cursor.
* ReplicatedLog: follower kvstore state ≡ leader state (bitwise, per
  leaf) after random mutation-window schedules.
* migration transparency (§10.2): a store migrating random live keys
  between windows returns bit-identical results to a never-migrated twin
  on every interleaved GET/UPDATE/DELETE window.
* atomic_var FAA: tickets are a permutation (mutual exclusion of tickets).
* checksum: detects any single-lane corruption; deterministic.
* lock-free fast path (§11): a lockfree twin store returns bit-identical
  results AND bit-identical state leaves to the locked spec on every
  random window, and the recorded concurrent history passes the
  tests/linearizability Wing–Gong checker.
* swappable backends (§14): random (P, B, op-mix, key-skew) window
  histories executed through the one-sided and active-message backends
  converge leaf-by-leaf — execution is backend-invariant; only the cost
  model differs.

Requires ``hypothesis`` (requirements-dev.txt); skips cleanly without it.
"""
import pytest

pytest.importorskip(
    "hypothesis",
    reason="property tests need hypothesis (pip install -r "
           "requirements-dev.txt); deterministic mirrors of the kvstore/row "
           "properties run in test_kvstore.py")

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core import (DELETE, GET, INSERT, NOP, UPDATE, AtomicVar,
                        KVStore, ReplicatedLog, Ringbuffer, SharedQueue,
                        make_manager)
from repro.core.ownedvar import checksum
from repro.core.replog import diverging_leaves

import test_channels as chmod
import test_kvstore as kvmod

P = 4

# ----------------------------------------------------------- kvstore lineariz.
op_strategy = st.tuples(
    st.sampled_from([NOP, GET, INSERT, UPDATE, DELETE]),
    st.integers(min_value=1, max_value=6))


@settings(max_examples=25, deadline=None)
@given(st.lists(st.lists(op_strategy, min_size=P, max_size=P),
                min_size=1, max_size=5))
def test_kvstore_linearizable_against_oracle(batches):
    rounds = []
    for rnd, ops in enumerate(batches):
        rounds.append([(op, key, kvmod.v(key, rnd)) for op, key in ops])
    kvmod.check_against_oracle(rounds)


# ------------------------------------------------- windowed kvstore lineariz.
@settings(max_examples=15, deadline=None)
@given(st.lists(
    st.lists(st.lists(op_strategy, min_size=2, max_size=2),
             min_size=P, max_size=P),
    min_size=1, max_size=3))
def test_kvstore_windows_linearizable_against_oracle(batches):
    """Random (P, B=2) windows replay against the oracle in the
    window-induced total order (GETs at window start; mutations in
    participant-then-window order)."""
    windows = []
    for rnd, lanes in enumerate(batches):
        windows.append([[(op, key, kvmod.v(key, rnd * 2 + b))
                         for b, (op, key) in enumerate(lane)]
                        for lane in lanes])
    kvmod.check_windows_against_oracle(windows)


# ------------------------------------------------- read-tier properties (§8)
@settings(max_examples=12, deadline=None)
@given(st.lists(
    st.lists(st.lists(op_strategy, min_size=2, max_size=2),
             min_size=P, max_size=P),
    min_size=1, max_size=4))
def test_cached_windows_never_return_stale_values(batches):
    """Random interleavings of writes and cached reads on a cache-enabled
    store match the sequential oracle — a GET served from the cache is
    indistinguishable from one served over the wire, under every
    insert/update/delete/reuse interleaving hypothesis finds."""
    windows = []
    for rnd, lanes in enumerate(batches):
        windows.append([[(op, key, kvmod.v(key, rnd * 2 + b))
                         for b, (op, key) in enumerate(lane)]
                        for lane in lanes])
    kvmod.check_windows_against_oracle(windows, store_mgr=kvmod.cmgr,
                                       store=kvmod.ckv)


@settings(max_examples=12, deadline=None)
@given(st.lists(
    st.lists(st.lists(op_strategy, min_size=2, max_size=2),
             min_size=P, max_size=P),
    min_size=1, max_size=4))
def test_cached_get_window_bitwise_equals_reference(batches):
    """After every window of a random mutation history, the cached read
    path and ``_get_window_reference`` return bit-identical (values,
    found) on the same state (the §8.2 validation protocol never serves a
    row the wire would not)."""
    import jax.numpy as jnp
    state = kvmod.ckv.init_state()
    probe = jnp.broadcast_to(jnp.arange(1, 9, dtype=jnp.uint32), (P, 8))
    for rnd, lanes in enumerate(batches):
        op = jnp.asarray([[o for o, _k in lane] for lane in lanes],
                         jnp.int32)
        key = jnp.asarray([[k for _o, k in lane] for lane in lanes],
                          jnp.uint32)
        val = jnp.asarray([[kvmod.v(k, rnd * 2 + b)
                            for b, (_o, k) in enumerate(lane)]
                           for lane in lanes], jnp.int32)
        state, _res = kvmod.cached_window_step(state, op, key, val)
        (cv, cf), (rv, rf) = kvmod.cached_vs_reference_reads(state, probe)
        np.testing.assert_array_equal(np.asarray(cf), np.asarray(rf))
        np.testing.assert_array_equal(np.asarray(cv), np.asarray(rv))


# ------------------------------------------------------------- row encoding
word = st.integers(min_value=-2**31, max_value=2**31 - 1)


@settings(max_examples=40, deadline=None)
@given(st.lists(word, min_size=kvmod.W, max_size=kvmod.W),
       st.integers(min_value=0, max_value=2**32 - 1),
       st.booleans(),
       st.integers(min_value=0, max_value=kvmod.W + 1),
       st.integers(min_value=1, max_value=2**31 - 1))
def test_encode_row_checksum_catches_single_word_tear(payload, ctr, valid,
                                                      pos, delta):
    kv = kvmod.kv
    row = kv.encode_row(jnp.asarray(payload, jnp.int32),
                        jnp.uint32(ctr), valid)
    _p, _c, _v, ok = kv.decode_row(row)
    assert bool(ok)
    torn = row.at[pos].set(row[pos] ^ jnp.int32(delta))
    if bool(jnp.all(torn == row)):
        return              # delta was a no-op on this word
    _p, _c, _v, ok = kv.decode_row(torn)
    assert not bool(ok), f"tear at word {pos} must break the checksum"


@settings(max_examples=25, deadline=None)
@given(st.lists(st.tuples(
    st.lists(word, min_size=kvmod.W, max_size=kvmod.W),
    st.integers(min_value=1, max_value=2**32 - 1),
    st.booleans(), st.booleans()),
    min_size=1, max_size=6))
def test_decode_row_case_analysis_elementwise(rows_spec):
    """Appendix C counter/valid cases over a batched row set: a row is
    accepted iff clean, valid and counter-current — checked elementwise
    under vmap exactly as the batched read path applies it."""
    kv = kvmod.kv
    rows, expect = [], []
    for payload, ctr, valid, stale in rows_spec:
        rows.append(kv.encode_row(jnp.asarray(payload, jnp.int32),
                                  jnp.uint32(ctr), valid))
        # the index advertises ctr; a stale replica advertises ctr-1
        expect.append(valid and not stale)
    batch = jnp.stack(rows)
    payloads, ctrs, valids, oks = jax.vmap(kv.decode_row)(batch)
    idx_ctr = jnp.asarray(
        [c - 1 if stale else c for (_p, c, _v, stale) in rows_spec],
        jnp.uint32)
    accept = np.asarray(oks) & np.asarray(valids) & \
        (np.asarray(ctrs) == np.asarray(idx_ctr))
    np.testing.assert_array_equal(accept, np.asarray(expect, bool))
    for i, (payload, _c, _v, _s) in enumerate(rows_spec):
        np.testing.assert_array_equal(np.asarray(payloads)[i],
                                      np.asarray(payload, np.int32))


# ------------------------------------------------- hash-index invariants
# one harness per configuration, shared across examples (state is rebuilt
# per example; the jitted apply/lookup callables are what we reuse)
_H8 = kvmod._ApplyHarness(C=8, S=32)
_HV16 = kvmod._ApplyHarness(C=16, S=32)
_HS16 = kvmod._ApplyHarness(C=16, S=32)


@settings(max_examples=20, deadline=None)
@given(st.lists(st.tuples(st.booleans(),
                          st.integers(min_value=1, max_value=6)),
                min_size=1, max_size=14),
       st.integers(min_value=0, max_value=3))
def test_hash_index_lookup_pinned_to_reference_scan(chain, seed):
    """After any protocol-valid tracker stream (same-key records alternate
    insert/delete), the O(PROBE) hash probe is bit-for-bit equal to the
    O(C) reference scan on the same state — found, pos, node, slot and ctr,
    across collision chains, wraparound and tombstones (C=8 forces all
    three)."""
    h = _H8
    live, entries, ctr = {}, [], 0
    for want_ins, key in chain:
        if live.get(key):
            entries.append((2, key) + live[key])
            live[key] = None
        elif want_ins:
            ctr += 1
            loc = ((key + seed) % P, ctr % 16, ctr)
            entries.append((1, key) + loc)
            live[key] = loc
    if not entries:
        return
    state, applied = h.apply(h.init(), kvmod._recs(*entries))
    probe_keys = list(range(1, 12))
    a = h.lookup(state, probe_keys, "hash")
    b = h.lookup(state, probe_keys, "ref")
    for la, lb in zip(a, b):
        np.testing.assert_array_equal(la, lb)
    # every live key is reachable; dead/absent keys are not (keys are
    # capped at 6 < C so no insert can overflow the window)
    found = dict(zip(probe_keys, np.asarray(a[0], bool)))
    for key in range(1, 7):
        assert found[key] == bool(live.get(key))


@settings(max_examples=20, deadline=None)
@given(st.lists(st.tuples(st.booleans(),
                          st.integers(min_value=1, max_value=6)),
                min_size=1, max_size=12))
def test_tracker_apply_vectorized_equals_sequential(chain):
    """The wave-scheduled tracker apply is logically equivalent to the
    sequential reference sweep on adversarial same-key chains: identical
    applied flags, per-key logical lookups, free-stack effects and
    overflow latch."""
    live, entries, ctr = {}, [], 0
    for want_ins, key in chain:
        if live.get(key):
            entries.append((2, key) + live[key])
            live[key] = None
        elif want_ins:
            ctr += 1
            loc = (key % P, ctr % 16, ctr)
            entries.append((1, key) + loc)
            live[key] = loc
    if not entries:
        return
    kvmod.TestTrackerApplyEquivalence()._check(
        kvmod._recs(*entries), hv=_HV16, hs=_HS16)


# ----------------------------------------------------------------- queue FIFO
qmgr = make_manager(P)
q = SharedQueue(None, "pq", qmgr, slots_per_node=3, width=1)


@jax.jit
def q_step(st, enq_want, enq_val, deq_want):
    def prog(st, ew, ev, dw):
        st, eok = q.enqueue(st, ev, want=ew)
        st, val, dok = q.dequeue(st, want=dw)
        return st, eok, val, dok
    return qmgr.runtime.run(prog, st, enq_want, enq_val, deq_want)


@settings(max_examples=25, deadline=None)
@given(st.lists(st.tuples(
    st.lists(st.booleans(), min_size=P, max_size=P),
    st.lists(st.booleans(), min_size=P, max_size=P)),
    min_size=1, max_size=5))
def test_queue_fifo_no_loss_no_dup(rounds):
    state = q.init_state()
    pushed, popped = [], []
    counter = 0
    for enq_wants, deq_wants in rounds:
        vals = []
        for w in enq_wants:
            vals.append(counter if w else -1)
            counter += 1
        state, eok, dval, dok = q_step(
            state,
            jnp.asarray(enq_wants), jnp.asarray(vals, jnp.int32)[:, None],
            jnp.asarray(deq_wants))
        eok, dval, dok = (np.asarray(eok), np.asarray(dval), np.asarray(dok))
        # enqueue grants in participant order
        for p in range(P):
            if eok[p]:
                pushed.append(vals[p])
        for p in range(P):
            if dok[p]:
                popped.append(int(dval[p, 0]))
    # FIFO w.r.t. grant order: popped must be a prefix-sequence of pushed
    assert popped == pushed[:len(popped)]
    assert len(set(popped)) == len(popped)          # no duplication
    assert len(popped) <= len(pushed)               # pop ≤ push


# ------------------------------------------------- windowed queue (§9.1)
class _QueueWindowHarness:
    """One jitted windowed-round callable per (P, B, slots_per_node)
    configuration, shared across hypothesis examples (state is rebuilt per
    example)."""

    _cache = {}

    def __new__(cls, nP, B, spn):
        key = (nP, B, spn)
        if key not in cls._cache:
            cls._cache[key] = super().__new__(cls)
            cls._cache[key]._build(nP, B, spn)
        return cls._cache[key]

    def _build(self, nP, B, spn):
        self.P, self.B = nP, B
        self.mgr = make_manager(nP)
        self.q = SharedQueue(None, f"pqw_{nP}_{B}_{spn}", self.mgr,
                             slots_per_node=spn, width=1)

        @jax.jit
        def step(st, ew, ev, dw):
            def prog(st, ew, ev, dw):
                st, g = self.q.enqueue_window(st, ev, ew)
                st, v, ok = self.q.dequeue_window(st, dw)
                return st, g, v, ok
            return self.mgr.runtime.run(prog, st, ew, ev, dw)

        self.step = step


def check_queue_windows(nP, B, spn, rounds):
    """rounds: list of ((P,B) enq wants, (P,B) deq wants) bool nests."""
    h = _QueueWindowHarness(nP, B, spn)
    oracle = chmod.QueueWindowOracle(h.q.capacity)
    st = h.q.init_state()
    counter = 0
    pushed, popped = [], []
    for ew, dw in rounds:
        ew = np.asarray(ew, bool).reshape(nP, B)
        dw = np.asarray(dw, bool).reshape(nP, B)
        ev = np.arange(counter, counter + nP * B, dtype=np.int32) \
            .reshape(nP, B, 1)
        counter += nP * B
        st, g, v, ok = h.step(st, jnp.asarray(ew), jnp.asarray(ev),
                              jnp.asarray(dw))
        g, v, ok = np.asarray(g), np.asarray(v), np.asarray(ok)
        eg = oracle.enqueue(ew, ev)
        dg, dv = oracle.dequeue(dw)
        np.testing.assert_array_equal(g, eg)
        np.testing.assert_array_equal(ok, dg)
        for (p, b), val in dv.items():
            np.testing.assert_array_equal(v[p, b], val)
        # ticket conservation: collect grant-ordered push/pop streams
        for p in range(nP):
            for b in range(B):
                if eg[p, b]:
                    pushed.append(int(ev[p, b, 0]))
                if dg[p, b]:
                    popped.append(int(v[p, b, 0]))
    assert popped == pushed[:len(popped)]          # FIFO, no loss
    assert len(set(popped)) == len(popped)         # no duplication
    assert len(popped) <= len(pushed)              # pop ≤ push


@settings(max_examples=20, deadline=None)
@given(st.sampled_from([2, 4]), st.sampled_from([1, 2, 3]),
       st.sampled_from([1, 2]), st.data())
def test_queue_windows_fifo_ticket_conservation(nP, B, spn, data):
    lane = st.lists(st.booleans(), min_size=nP * B, max_size=nP * B)
    rounds = data.draw(st.lists(st.tuples(lane, lane),
                                min_size=1, max_size=4))
    check_queue_windows(nP, B, spn, rounds)


# ------------------------------------------------- ringbuffer fuzz (§9.2)
_rb_mgr = make_manager(P)
_rb = Ringbuffer(None, "prb", _rb_mgr, owner=0, capacity=6, width=3)


@jax.jit
def _rb_fill(st, msgs, lens):
    def prog(st, msgs, lens):
        st, sent, _ = _rb.publish_window(st, msgs, lens)
        return st, sent
    return _rb_mgr.runtime.run(prog, st, msgs, lens)


@jax.jit
def _rb_drain(st):
    def prog(st):
        return _rb.recv_window(st, 4)
    return _rb_mgr.runtime.run(prog, st)


def check_ringbuffer_corruption(victim, field, slot, word, delta):
    """Publish 4 known messages, corrupt one word of one consumer's
    cached slot state, drain: every lane the consumer reports ``got``
    must carry exactly the published message + length for its cursor
    position — corruption may stall delivery, never forge it."""
    msgs = np.arange(12, dtype=np.int32).reshape(4, 3) * 7 + 1
    lens = np.asarray([3, 2, 1, 3], np.int32)
    st, sent = _rb_fill(
        _rb.init_state(),
        jnp.broadcast_to(jnp.asarray(msgs), (P, 4, 3)),
        jnp.broadcast_to(jnp.asarray(lens), (P, 4)))
    assert np.all(np.asarray(sent)[0])
    buf = np.asarray(getattr(st, field)).copy()
    if field == "payload":
        buf[victim, slot, word] += delta
    else:
        buf[victim, slot] += np.asarray(delta, buf.dtype)
    changed = not np.array_equal(buf, np.asarray(getattr(st, field)))
    st = st._replace(**{field: jnp.asarray(buf)})
    _st2, m, l, got, _f = _rb_drain(st)
    m, l, got = np.asarray(m), np.asarray(l), np.asarray(got)
    for p in range(P):
        for k in range(4):
            if got[p, k]:
                np.testing.assert_array_equal(
                    m[p, k], msgs[k],
                    err_msg=f"consumer {p} lane {k} forged a message "
                            f"after {field} corruption")
                assert l[p, k] == lens[k]
    # a consumer with a corrupted live slot must stall at or before it
    if changed and slot < 4 and field in ("payload", "seq", "length",
                                          "csum"):
        assert not got[victim, slot:].any(), \
            f"corrupted {field} word validated at consumer {victim}"


@settings(max_examples=30, deadline=None)
@given(st.integers(min_value=0, max_value=P - 1),
       st.sampled_from(["payload", "seq", "length", "csum"]),
       st.integers(min_value=0, max_value=5),
       st.integers(min_value=0, max_value=2),
       st.integers(min_value=1, max_value=2**31 - 1))
def test_ringbuffer_corruption_never_forges_messages(victim, field, slot,
                                                     word, delta):
    check_ringbuffer_corruption(victim, field, slot, word, delta)


# ------------------------------------------------- replicated log (§9.3)
_rl_mgr = make_manager(P)
_rl_kw = dict(slots_per_node=4, value_width=2, num_locks=8,
              index_capacity=64)
_rl_leader = KVStore(None, "prl_leader", _rl_mgr, **_rl_kw)
_rl_follower = KVStore(None, "prl_follower", _rl_mgr, **_rl_kw)
_rl_log = ReplicatedLog(None, "prl_log", _rl_mgr, store=_rl_leader,
                        window=2, capacity=2)


@jax.jit
def _rl_step(lst, fst, gst, op, key, val):
    def prog(lst, fst, gst, op, key, val):
        lst, _res = _rl_leader.op_window(lst, op, key, val)
        gst, ok = _rl_log.append(gst, op, key, val)
        gst, fst, _n = _rl_log.sync(gst, _rl_follower, fst, max_entries=1)
        return lst, fst, gst, ok
    return _rl_mgr.runtime.run(prog, lst, fst, gst, op, key, val)


def check_replog_convergence(batches):
    """batches: rounds of P lanes × B=2 of (op, key) — replay on the
    leader, replicate each window, require bitwise leader ≡ follower on
    every state leaf (cache excluded: local read policy) after every
    window."""
    lst, fst = _rl_leader.init_state(), _rl_follower.init_state()
    gst = _rl_log.init_state()
    for rnd, lanes in enumerate(batches):
        op = jnp.asarray([[o for o, _k in lane] for lane in lanes],
                         jnp.int32)
        key = jnp.asarray([[k for _o, k in lane] for lane in lanes],
                          jnp.uint32)
        val = jnp.asarray([[kvmod.v(k, rnd * 2 + b)
                            for b, (_o, k) in enumerate(lane)]
                           for lane in lanes], jnp.int32)
        lst, fst, gst, ok = _rl_step(lst, fst, gst, op, key, val)
        assert np.all(np.asarray(ok)), "sync-after-append never drops"
        diverged = diverging_leaves(lst, fst)
        assert not diverged, \
            f"leader/follower diverged on {diverged} after window {rnd}"


@settings(max_examples=10, deadline=None)
@given(st.lists(
    st.lists(st.lists(op_strategy, min_size=2, max_size=2),
             min_size=P, max_size=P),
    min_size=1, max_size=3))
def test_replog_follower_state_equals_leader(batches):
    check_replog_convergence(batches)


# ---------------------------------------------- migration transparency (§10)
_mig_mgr = make_manager(P)
_mig_kw = dict(slots_per_node=4, value_width=2, num_locks=8,
               index_capacity=64)
mig_kv = KVStore(None, "prop_mig", _mig_mgr, **_mig_kw)
mig_twin = KVStore(None, "prop_mig_twin", _mig_mgr, **_mig_kw)


@jax.jit
def _mig_window(st, op, key, val):
    return _mig_mgr.runtime.run(mig_kv.op_window, st, op, key, val)


@jax.jit
def _twin_window(st, op, key, val):
    return _mig_mgr.runtime.run(mig_twin.op_window, st, op, key, val)


@jax.jit
def _mig_move(st, keys, dests):
    return _mig_mgr.runtime.run(mig_kv.migrate_window, st, keys, dests)


def _mig_prefill(step, kv_):
    st = kv_.init_state()
    op = jnp.asarray([[INSERT, INSERT], [INSERT, INSERT],
                      [INSERT, NOP], [INSERT, NOP]], jnp.int32)
    key = jnp.asarray([[1, 5], [2, 6], [3, 1], [4, 1]], jnp.uint32)
    val = jnp.asarray([[kvmod.v(1), kvmod.v(5)], [kvmod.v(2), kvmod.v(6)],
                       [kvmod.v(3), kvmod.v(3)], [kvmod.v(4), kvmod.v(4)]],
                      jnp.int32)
    st, _res = step(st, op, key, val)
    return st


interleave_op = st.tuples(st.sampled_from([NOP, GET, UPDATE, DELETE]),
                          st.integers(min_value=1, max_value=6))


@settings(max_examples=10, deadline=None)
@given(st.lists(
    st.tuples(
        st.lists(st.lists(interleave_op, min_size=2, max_size=2),
                 min_size=P, max_size=P),
        st.lists(st.tuples(st.integers(min_value=1, max_value=6),
                           st.integers(min_value=0, max_value=P - 1)),
                 min_size=P, max_size=P)),
    min_size=1, max_size=4))
def test_migration_transparent_to_interleaved_ops(rounds):
    """The §10.2 transparency contract, fuzzed: a store that migrates
    random live keys to random destinations between windows returns
    bit-for-bit the (value, found, retries) lanes of a never-migrated
    twin on every interleaved GET/UPDATE/DELETE window — wherever a row
    lives, reads and writes behave identically (moves may themselves
    fail on full destinations; that too must be invisible)."""
    st_a = _mig_prefill(_mig_window, mig_kv)
    st_b = _mig_prefill(_twin_window, mig_twin)
    for rnd, (lanes, moves) in enumerate(rounds):
        op = jnp.asarray([[o for o, _k in lane] for lane in lanes],
                         jnp.int32)
        key = jnp.asarray([[k for _o, k in lane] for lane in lanes],
                          jnp.uint32)
        val = jnp.asarray([[kvmod.v(k, rnd * 2 + b)
                            for b, (_o, k) in enumerate(lane)]
                           for lane in lanes], jnp.int32)
        st_a, res_a = _mig_window(st_a, op, key, val)
        st_b, res_b = _twin_window(st_b, op, key, val)
        for la, lb in zip(res_a, res_b):
            np.testing.assert_array_equal(np.asarray(la), np.asarray(lb),
                                          err_msg=f"window {rnd}")
        mk = jnp.asarray([[m[0]] for m in moves], jnp.uint32)
        md = jnp.asarray([[m[1]] for m in moves], jnp.int32)
        st_a, _moved = _mig_move(st_a, mk, md)


# ---------------------------------------------- lock-free fast path (§11)
_lf_mgr = make_manager(P)
_lf_kw = dict(slots_per_node=8, value_width=2, num_locks=8,
              index_capacity=64)
_lf_locked = KVStore(None, "plf_locked", _lf_mgr, **_lf_kw)
_lf_fast = KVStore(None, "plf_fast", _lf_mgr, lockfree=True, **_lf_kw)


@jax.jit
def _lf_step(lst, fst, op, key, val):
    def prog(lst, fst, op, key, val):
        lst, ra = _lf_locked.op_window(lst, op, key, val)
        fst, rb = _lf_fast.op_window(fst, op, key, val)
        return lst, fst, ra, rb
    return _lf_mgr.runtime.run(prog, lst, fst, op, key, val)


@settings(max_examples=15, deadline=None)
@given(st.lists(
    st.lists(st.lists(op_strategy, min_size=2, max_size=2),
             min_size=P, max_size=P),
    min_size=1, max_size=4))
def test_lockfree_windows_bitwise_equal_locked_and_linearizable(batches):
    """The §11 pinning property: on every random window history, the
    lock-free store (commuting windows served without lock acquisition,
    mixed windows falling back) commits bit-identical state leaves and
    result lanes to the locked executable spec — and the recorded
    concurrent history passes the torture harness's linearizability
    checker."""
    from linearizability import HistoryRecorder, KVSpec, check_history
    lst, fst = _lf_locked.init_state(), _lf_fast.init_state()
    rec = HistoryRecorder()
    for rnd, lanes in enumerate(batches):
        op = jnp.asarray([[o for o, _k in lane] for lane in lanes],
                         jnp.int32)
        key = jnp.asarray([[k for _o, k in lane] for lane in lanes],
                          jnp.uint32)
        val = jnp.asarray([[kvmod.v(k, rnd * 2 + b)
                            for b, (_o, k) in enumerate(lane)]
                           for lane in lanes], jnp.int32)
        lst, fst, ra, rb = _lf_step(lst, fst, op, key, val)
        for la, lb in zip(ra, rb):
            np.testing.assert_array_equal(np.asarray(la), np.asarray(lb),
                                          err_msg=f"window {rnd}")
        diverged = diverging_leaves(lst, fst)
        assert not diverged, \
            f"lockfree diverged from locked spec on {diverged} " \
            f"after window {rnd}"
        rec.record_kv_window(op, key, val, rb)
    violation = check_history(KVSpec(2), rec.windows)
    assert violation is None, str(violation)


# ---------------------------------------------- swappable backends (§14)
class _BackendDiffHarness:
    """Twin hashed-placement stores — one per execution backend — jitted
    once per (P, B) configuration and shared across examples."""

    _cache = {}

    def __new__(cls, nP, B):
        key = (nP, B)
        if key not in cls._cache:
            cls._cache[key] = super().__new__(cls)
            cls._cache[key]._build(nP, B)
        return cls._cache[key]

    def _build(self, nP, B):
        self.stores = {}
        for bk in ("onesided", "active_message", "pallas"):
            mgr = make_manager(nP, backend=bk)
            kv = KVStore(None, f"pbk_{bk}_{nP}_{B}", mgr,
                         slots_per_node=8, value_width=2, num_locks=8,
                         index_capacity=64, placement="hashed")
            step = jax.jit(lambda s, o, k, v, kv=kv, mgr=mgr:
                           mgr.runtime.run(kv.op_window, s, o, k, v))
            self.stores[bk] = (kv, step)


@settings(max_examples=10, deadline=None)
@given(st.sampled_from([2, 4]), st.sampled_from([1, 2]),
       st.integers(min_value=2, max_value=8), st.data())
def test_backend_differential_windows_converge_leafwise(nP, B, key_space,
                                                        data):
    """The §14/§15 differential property: random (P, B, op-mix,
    key-skew) window histories executed through the one-sided,
    active-message, and pallas remote-DMA backends converge leaf-by-leaf
    — every per-window result lane AND every state leaf (rows, index,
    locks, free stacks, counters) is bitwise identical after every
    window.  ``key_space`` doubles as the skew knob: 2 keys ≈ maximal
    contention, 8 ≈ spread."""
    h = _BackendDiffHarness(nP, B)
    op_t = st.tuples(st.sampled_from([NOP, GET, INSERT, UPDATE, DELETE]),
                     st.integers(min_value=1, max_value=key_space))
    batches = data.draw(st.lists(
        st.lists(st.lists(op_t, min_size=B, max_size=B),
                 min_size=nP, max_size=nP),
        min_size=1, max_size=3))
    states = {bk: kv.init_state() for bk, (kv, _s) in h.stores.items()}
    for rnd, lanes in enumerate(batches):
        op = jnp.asarray([[o for o, _k in lane] for lane in lanes],
                         jnp.int32)
        key = jnp.asarray([[k for _o, k in lane] for lane in lanes],
                          jnp.uint32)
        val = jnp.asarray([[kvmod.v(k, rnd * B + b)
                            for b, (_o, k) in enumerate(lane)]
                           for lane in lanes], jnp.int32)
        res = {}
        for bk, (_kv, step) in h.stores.items():
            states[bk], res[bk] = step(states[bk], op, key, val)
        for bk in ("active_message", "pallas"):
            for la, lb in zip(jax.tree.leaves(res["onesided"]),
                              jax.tree.leaves(res[bk])):
                np.testing.assert_array_equal(
                    np.asarray(la), np.asarray(lb),
                    err_msg=f"{bk} window {rnd}")
            for la, lb in zip(jax.tree.leaves(states["onesided"]),
                              jax.tree.leaves(states[bk])):
                np.testing.assert_array_equal(
                    np.asarray(la), np.asarray(lb),
                    err_msg=f"{bk} state leaf after window {rnd}")


# ------------------------------------------------------------------ FAA tickets
amgr = make_manager(P)
av = AtomicVar(None, "pa", amgr, host=0, dtype=jnp.int32)


@jax.jit
def faa_step(st, want):
    def prog(st, w):
        st, old, _ = av.fetch_add(st, 1, pred=w)
        return st, old
    return amgr.runtime.run(prog, st, want)


@settings(max_examples=30, deadline=None)
@given(st.lists(st.lists(st.booleans(), min_size=P, max_size=P),
                min_size=1, max_size=6))
def test_faa_tickets_form_permutation(rounds):
    state = av.init_state(0)
    tickets = []
    for wants in rounds:
        state, old = faa_step(state, jnp.asarray(wants))
        old = np.asarray(old)
        for p in range(P):
            if wants[p]:
                tickets.append(int(old[p]))
    assert sorted(tickets) == list(range(len(tickets)))


# ------------------------------------------------------------------- checksum
@settings(max_examples=40, deadline=None)
@given(st.lists(st.integers(min_value=-2**31, max_value=2**31 - 1),
                min_size=1, max_size=16),
       st.integers(min_value=0, max_value=15),
       st.integers(min_value=1, max_value=2**31 - 1))
def test_checksum_detects_single_lane_corruption(words, pos, delta):
    x = jnp.asarray(words, jnp.int32)
    c1 = checksum(x)
    y = x.at[pos % len(words)].add(jnp.int32(delta))
    c2 = checksum(y)
    if bool(jnp.all(x == y)):  # delta wrapped to zero — no corruption
        assert int(c1) == int(c2)
    else:
        assert int(c1) != int(c2)
    # determinism
    assert int(checksum(x)) == int(c1)
