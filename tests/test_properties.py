"""Property-based tests (hypothesis) for channel invariants.

Invariants checked:
* kvstore linearizability: random op batches match the sequential oracle
  over the induced linearization order (Appendix C).
* shared queue: FIFO, no loss, no duplication, pop≤push.
* atomic_var FAA: tickets are a permutation (mutual exclusion of tickets).
* checksum: detects any single-lane corruption; deterministic.
"""
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core import (DELETE, GET, INSERT, NOP, UPDATE, AtomicVar,
                        SharedQueue, make_manager)
from repro.core.ownedvar import checksum

import test_kvstore as kvmod

P = 4

# ----------------------------------------------------------- kvstore lineariz.
op_strategy = st.tuples(
    st.sampled_from([NOP, GET, INSERT, UPDATE, DELETE]),
    st.integers(min_value=1, max_value=6))


@settings(max_examples=25, deadline=None)
@given(st.lists(st.lists(op_strategy, min_size=P, max_size=P),
                min_size=1, max_size=5))
def test_kvstore_linearizable_against_oracle(batches):
    rounds = []
    for rnd, ops in enumerate(batches):
        rounds.append([(op, key, kvmod.v(key, rnd)) for op, key in ops])
    kvmod.check_against_oracle(rounds)


# ----------------------------------------------------------------- queue FIFO
qmgr = make_manager(P)
q = SharedQueue(None, "pq", qmgr, slots_per_node=3, width=1)


@jax.jit
def q_step(st, enq_want, enq_val, deq_want):
    def prog(st, ew, ev, dw):
        st, eok = q.enqueue(st, ev, want=ew)
        st, val, dok = q.dequeue(st, want=dw)
        return st, eok, val, dok
    return qmgr.runtime.run(prog, st, enq_want, enq_val, deq_want)


@settings(max_examples=25, deadline=None)
@given(st.lists(st.tuples(
    st.lists(st.booleans(), min_size=P, max_size=P),
    st.lists(st.booleans(), min_size=P, max_size=P)),
    min_size=1, max_size=5))
def test_queue_fifo_no_loss_no_dup(rounds):
    state = q.init_state()
    pushed, popped = [], []
    counter = 0
    for enq_wants, deq_wants in rounds:
        vals = []
        for w in enq_wants:
            vals.append(counter if w else -1)
            counter += 1
        state, eok, dval, dok = q_step(
            state,
            jnp.asarray(enq_wants), jnp.asarray(vals, jnp.int32)[:, None],
            jnp.asarray(deq_wants))
        eok, dval, dok = (np.asarray(eok), np.asarray(dval), np.asarray(dok))
        # enqueue grants in participant order
        for p in range(P):
            if eok[p]:
                pushed.append(vals[p])
        for p in range(P):
            if dok[p]:
                popped.append(int(dval[p, 0]))
    # FIFO w.r.t. grant order: popped must be a prefix-sequence of pushed
    assert popped == pushed[:len(popped)]
    assert len(set(popped)) == len(popped)          # no duplication
    assert len(popped) <= len(pushed)               # pop ≤ push


# ------------------------------------------------------------------ FAA tickets
amgr = make_manager(P)
av = AtomicVar(None, "pa", amgr, host=0, dtype=jnp.int32)


@jax.jit
def faa_step(st, want):
    def prog(st, w):
        st, old, _ = av.fetch_add(st, 1, pred=w)
        return st, old
    return amgr.runtime.run(prog, st, want)


@settings(max_examples=30, deadline=None)
@given(st.lists(st.lists(st.booleans(), min_size=P, max_size=P),
                min_size=1, max_size=6))
def test_faa_tickets_form_permutation(rounds):
    state = av.init_state(0)
    tickets = []
    for wants in rounds:
        state, old = faa_step(state, jnp.asarray(wants))
        old = np.asarray(old)
        for p in range(P):
            if wants[p]:
                tickets.append(int(old[p]))
    assert sorted(tickets) == list(range(len(tickets)))


# ------------------------------------------------------------------- checksum
@settings(max_examples=40, deadline=None)
@given(st.lists(st.integers(min_value=-2**31, max_value=2**31 - 1),
                min_size=1, max_size=16),
       st.integers(min_value=0, max_value=15),
       st.integers(min_value=1, max_value=2**31 - 1))
def test_checksum_detects_single_lane_corruption(words, pos, delta):
    x = jnp.asarray(words, jnp.int32)
    c1 = checksum(x)
    y = x.at[pos % len(words)].add(jnp.int32(delta))
    c2 = checksum(y)
    if bool(jnp.all(x == y)):  # delta wrapped to zero — no corruption
        assert int(c1) == int(c2)
    else:
        assert int(c1) != int(c2)
    # determinism
    assert int(checksum(x)) == int(c1)
