"""The explicit locality tier (DESIGN.md §10): placement policies, online
MOVE migration, the HotTracker heat channel and rebalance().

Checked here:

* placement policies home INSERTs correctly (``hashed`` → key % P,
  ``explicit`` → the per-lane target) and the windowed oracle semantics
  survive — capacity accounting follows the HOME node's free stack, not
  the writer's;
* reads served by a row's home node cost ZERO modeled wire bytes
  (placement is the §2.3 locality story made controllable);
* ``migrate_window`` re-homes live rows: index entries re-point on every
  participant (hash and flat lookups stay pinned), values survive, the
  vacated slot returns to the old home's free stack with a bumped reuse
  counter, moves of absent keys / to full destinations fail cleanly with
  the row intact, and self-moves succeed with no effect;
* migrated stores stay **result-for-result identical** to never-migrated
  ones under interleaved GET/UPDATE/DELETE (the §10.2 transparency
  contract), with ``_migrate_reference`` retained as the sequential spec;
* MOVE records ride the ReplicatedLog: followers replay migrations
  through the placed service path and converge bitwise;
* HotTracker decay/observe semantics and rebalance(): rows whose
  dominant reader is remote move to that reader and the skewed-reader
  read window's modeled wire bytes collapse.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (DELETE, GET, INSERT, MOVE, NOP, UPDATE, HotTracker,
                        KVStore, ReplicatedLog, make_manager)
from repro.core.kvstore import IDX_NODE, IDX_SLOT, IDX_STATE, _USED
from repro.core.replog import diverging_leaves

from test_kvstore import Oracle, assert_lookup_pinned

P = 4
S = 4
W = 2

mgr = make_manager(P)
_kw = dict(slots_per_node=S, value_width=W, num_locks=8, index_capacity=64)
kv_hashed = KVStore(None, "loc_hashed", mgr, placement="hashed", **_kw)
kv_expl = KVStore(None, "loc_expl", mgr, placement="explicit", **_kw)
kv_mig = KVStore(None, "loc_mig", mgr, **_kw)
kv_plain = KVStore(None, "loc_plain", mgr, **_kw)


def tstep(kv):
    @jax.jit
    def f(st, op, key, val, tgt):
        return mgr.runtime.run(
            lambda s, o, k, v, t: kv.op_window(s, o, k, v, targets=t),
            st, op, key, val, tgt)
    return f


def migf(kv):
    @jax.jit
    def f(st, keys, dests, preds):
        return mgr.runtime.run(kv.migrate_window, st, keys, dests, preds)
    return f


def arrs(window):
    op = jnp.asarray([[o[0] for o in ln] for ln in window], jnp.int32)
    key = jnp.asarray([[o[1] for o in ln] for ln in window], jnp.uint32)
    val = jnp.asarray([[o[2] for o in ln] for ln in window], jnp.int32)
    tgt = jnp.asarray([[o[3] if len(o) > 3 else 0 for o in ln]
                       for ln in window], jnp.int32)
    return op, key, val, tgt


class PlacedOracle(Oracle):
    """The sequential oracle with a home function: INSERT capacity follows
    the HOME node's free stack (§10.1), not the writer's."""

    def __init__(self, home_fn, slots=S):
        super().__init__(slots=slots)
        self.home_fn = home_fn

    def _mod(self, p, op, key, val, tgt=0):
        if op == INSERT:
            home = self.home_fn(p, key, tgt)
            if key not in self.map and self.free[home] > 0:
                self.map[key] = tuple(val)
                self.loc[key] = home
                self.free[home] -= 1
                return True
            return False
        if op == MOVE:
            if key not in self.map:
                return False
            dest = int(tgt)
            if dest == self.loc[key]:
                return True
            if self.free[dest] <= 0:
                return False
            self.free[self.loc[key]] += 1
            self.loc[key] = dest
            self.free[dest] -= 1
            return True
        return super()._mod(p, op, key, val)

    def apply_window(self, window):
        pre = dict(self.map)
        results = [[None] * len(lane) for lane in window]
        for p, lane in enumerate(window):
            for b, op_t in enumerate(lane):
                if op_t[0] == GET:
                    results[p][b] = pre.get(op_t[1])
        for p, lane in enumerate(window):
            for b, op_t in enumerate(lane):
                op, key, val = op_t[0], op_t[1], op_t[2]
                tgt = op_t[3] if len(op_t) > 3 else 0
                if op in (INSERT, UPDATE, DELETE, MOVE):
                    results[p][b] = self._mod(p, op, key, val, tgt)
        return results


def drive_placed(kv, windows, oracle):
    st = kv.init_state()
    step = tstep(kv)
    for rnd, w in enumerate(windows):
        op, key, val, tgt = arrs(w)
        st, res = step(st, op, key, val, tgt)
        expect = oracle.apply_window(w)
        for p, lane in enumerate(w):
            for b, op_t in enumerate(lane):
                o, k = op_t[0], op_t[1]
                if o == NOP:
                    continue
                if o == GET:
                    exp = expect[p][b]
                    assert bool(res.found[p][b]) == (exp is not None), \
                        f"round {rnd} p{p}b{b} GET({k})"
                    if exp is not None:
                        np.testing.assert_array_equal(
                            np.asarray(res.value[p][b]), exp)
                else:
                    assert bool(res.found[p][b]) == expect[p][b], \
                        f"round {rnd} p{p}b{b} op{o}({k})"
    return st


def key_locations(st):
    """key → (node, slot) from participant 0's index (all participants
    apply identical tracker records, so the indexes agree)."""
    idx = np.asarray(st.idx[0])
    used = idx[:, IDX_STATE] == _USED
    return {int(np.uint32(r[1])): (int(r[IDX_NODE]), int(r[IDX_SLOT]))
            for r in idx[used]}


def v(key, salt=0):
    return (int(key) * 10 + salt, int(key) * 100 + salt)


NOPR = (NOP, 1, (0, 0), 0)


# ------------------------------------------------------ placement policies
class TestPlacementPolicies:
    def test_hashed_placement_homes_at_key_mod_p(self):
        windows = [[[(INSERT, 1 + p * 2 + b, v(1 + p * 2 + b), 0)
                     for b in range(2)] for p in range(P)]]
        oracle = PlacedOracle(lambda p, k, t: k % P)
        st = drive_placed(kv_hashed, windows, oracle)
        locs = key_locations(st)
        assert locs, "inserts must land"
        for k, (node, _slot) in locs.items():
            assert node == k % P, f"key {k} homed at {node}, want {k % P}"
        assert_lookup_pinned(kv_hashed, mgr, st)

    def test_hashed_oracle_with_mixed_windows(self):
        rng = np.random.default_rng(7)
        oracle = PlacedOracle(lambda p, k, t: k % P)
        windows = []
        for _ in range(6):
            w = []
            for p in range(P):
                lane = []
                for _b in range(2):
                    op = int(rng.choice([NOP, GET, INSERT, UPDATE, DELETE]))
                    k = int(rng.integers(1, 9))
                    lane.append((op, k, v(k, int(rng.integers(0, 5))), 0))
                w.append(lane)
            windows.append(w)
        drive_placed(kv_hashed, windows, oracle)

    def test_hashed_capacity_follows_home_stack(self):
        """P·S inserts that all hash to node 0: exactly S (node 0's
        stack) succeed — capacity is the HOME's, not the writer's."""
        keys = [P * (i + 1) for i in range(P * S)]     # all ≡ 0 (mod P)
        windows = [[[(INSERT, keys[p * S + b], v(keys[p * S + b]), 0)
                     for b in range(S)] for p in range(P)]]
        oracle = PlacedOracle(lambda p, k, t: k % P)
        st = drive_placed(kv_hashed, windows, oracle)
        locs = key_locations(st)
        assert len(locs) == S
        assert all(node == 0 for node, _ in locs.values())

    def test_explicit_placement_lands_at_targets(self):
        windows = [[[(INSERT, 1 + p, v(1 + p), (p + 1) % P)]
                    for p in range(P)]]
        oracle = PlacedOracle(lambda p, k, t: t)
        st = drive_placed(kv_expl, windows, oracle)
        locs = key_locations(st)
        for p in range(P):
            assert locs[1 + p][0] == (p + 1) % P

    def test_explicit_placement_requires_targets(self):
        with pytest.raises(ValueError, match="targets"):
            mgr.runtime.run(
                lambda s: kv_expl.op_window(
                    s, jnp.asarray([INSERT]), jnp.asarray([1], jnp.uint32),
                    jnp.zeros((1, W), jnp.int32)),
                kv_expl.init_state())

    def test_bad_placement_rejected(self):
        with pytest.raises(ValueError, match="placement"):
            KVStore(None, "loc_bad", mgr, placement="nope", **_kw)

    def test_home_reads_cost_zero_wire_bytes(self):
        """Every participant reads only keys homed at it: the §2.3
        locality fast path serves them from local memory — zero modeled
        read bytes, now under programmer-controlled placement."""
        windows = [[[(INSERT, 1 + p, v(1 + p), p)] for p in range(P)]]
        oracle = PlacedOracle(lambda p, k, t: t)
        st = drive_placed(kv_expl, windows, oracle)
        mgr.traffic.enable().reset()
        fresh = jax.jit(lambda s, k: mgr.runtime.run(
            lambda ss, kk: kv_expl.get_batch(ss, kk), s, k))
        me_keys = jnp.arange(1, P + 1, dtype=jnp.uint32).reshape(P, 1)
        _st, _v, found = fresh(st, me_keys)
        jax.block_until_ready(found)
        total = mgr.traffic.total_bytes()
        mgr.traffic.disable().reset()
        assert bool(jnp.all(found))
        assert total == 0.0, "home-placed reads must be wire-free"


# ------------------------------------------------------ MOVE / migration
class TestMigration:
    def _seed(self, kv):
        """Insert 2 keys per participant writer-locally; key 1+p and
        1+P+p live at node p."""
        windows = [[[(INSERT, 1 + p + P * b, v(1 + p + P * b), 0)
                     for b in range(2)] for p in range(P)]]
        oracle = PlacedOracle(lambda p, k, t: p)
        return drive_placed(kv, windows, oracle)

    def test_move_rehomes_and_preserves_values(self):
        st = self._seed(kv_mig)
        pre = key_locations(st)
        mig = migf(kv_mig)
        keys = jnp.arange(1, P + 1, dtype=jnp.uint32).reshape(P, 1)
        dests = jnp.asarray([[(p + 1) % P] for p in range(P)], jnp.int32)
        st, moved = mig(st, keys, dests, jnp.ones((P, 1), bool))
        assert bool(jnp.all(moved))
        locs = key_locations(st)
        for p in range(P):
            assert locs[1 + p][0] == (p + 1) % P
            assert pre[1 + P + p] == locs[1 + P + p]  # unmoved keys stay
        assert_lookup_pinned(kv_mig, mgr, st)
        getb = jax.jit(lambda s, k: mgr.runtime.run(
            lambda ss, kk: kv_mig.get_batch(ss, kk), s, k))
        gk = jnp.broadcast_to(jnp.arange(1, 2 * P + 1, dtype=jnp.uint32),
                              (P, 2 * P))
        _st2, vals, found = getb(st, gk)
        assert bool(jnp.all(found))
        np.testing.assert_array_equal(
            np.asarray(vals[..., 0]), np.asarray(gk, np.int32) * 10)

    def test_move_frees_old_slot_and_bumps_reuse_counter(self):
        st = self._seed(kv_mig)
        pre = key_locations(st)
        old_node, old_slot = pre[1]                     # key 1 lives at p0
        top_before = int(np.asarray(st.free_top)[old_node])
        ctr_before = int(np.asarray(st.slot_ctr)[old_node, old_slot])
        mig = migf(kv_mig)
        keys = jnp.concatenate([jnp.ones((1, 1), jnp.uint32),
                                jnp.zeros((P - 1, 1), jnp.uint32)])
        dests = jnp.full((P, 1), 1, jnp.int32)
        preds = jnp.asarray([[True]] + [[False]] * (P - 1))
        st, moved = mig(st, keys, dests, preds)
        assert bool(np.asarray(moved)[0, 0])
        # vacated slot is back on the old home's stack, counter bumped
        assert int(np.asarray(st.free_top)[old_node]) == top_before + 1
        stack = np.asarray(st.free_stack)[old_node]
        assert old_slot in stack[:top_before + 1]
        assert int(np.asarray(st.slot_ctr)[old_node, old_slot]) \
            == ctr_before + 1

    def test_move_of_absent_key_fails_cleanly(self):
        st = self._seed(kv_mig)
        mig = migf(kv_mig)
        keys = jnp.full((P, 1), 99, jnp.uint32)
        dests = jnp.zeros((P, 1), jnp.int32)
        preds = jnp.asarray([[True]] + [[False]] * (P - 1))
        st2, moved = mig(st, keys, dests, preds)
        assert not bool(np.asarray(moved)[0, 0])
        assert key_locations(st) == key_locations(st2)

    def test_move_to_current_home_is_a_successful_noop(self):
        st = self._seed(kv_mig)
        pre = key_locations(st)
        mig = migf(kv_mig)
        keys = jnp.asarray([[1 + p] for p in range(P)], jnp.uint32)
        dests = jnp.asarray([[p] for p in range(P)], jnp.int32)  # = homes
        st, moved = mig(st, keys, dests, jnp.ones((P, 1), bool))
        assert bool(jnp.all(moved))
        assert key_locations(st) == pre

    def test_move_to_full_destination_fails_with_row_intact(self):
        # fill node 0 completely: participant 0 inserts its 2 remaining
        # writer-local slots (placement "local" ignores INSERT targets)
        st = self._seed(kv_mig)   # node 0 already hosts 2 rows (S = 4)
        step = tstep(kv_mig)
        w = [[(INSERT, 100 + b, v(100 + b), 0) for b in range(2)]
             if p == 0 else [NOPR, NOPR] for p in range(P)]
        op, key, val, tgt = arrs(w)
        st, res = step(st, op, key, val, tgt)
        assert bool(jnp.all(res.found[0]))
        mig = migf(kv_mig)
        keys = jnp.asarray([[2]] + [[0]] * (P - 1), jnp.uint32)  # at node 1
        dests = jnp.zeros((P, 1), jnp.int32)                     # full node
        preds = jnp.asarray([[True]] + [[False]] * (P - 1))
        st2, moved = mig(st, keys, dests, preds)
        assert not bool(np.asarray(moved)[0, 0])
        assert key_locations(st2)[2] == key_locations(st)[2]
        getb = jax.jit(lambda s, k: mgr.runtime.run(
            lambda ss, kk: kv_mig.get_batch(ss, kk), s, k))
        _s, vals, found = getb(st2, jnp.full((P, 1), 2, jnp.uint32))
        assert bool(jnp.all(found))
        np.testing.assert_array_equal(np.asarray(vals[..., 0]), 20)

    def test_migrate_window_matches_reference_results(self):
        st_w = self._seed(kv_mig)
        st_r = st_w
        keys = jnp.asarray([[1 + p, 1 + P + p] for p in range(P)],
                           jnp.uint32)
        dests = jnp.asarray([[(p + 2) % P, (p + 1) % P] for p in range(P)],
                            jnp.int32)
        preds = jnp.ones((P, 2), bool)
        mig = migf(kv_mig)
        ref = jax.jit(lambda s, k, d, p: mgr.runtime.run(
            kv_mig._migrate_reference, s, k, d, p))
        st_w, moved_w = mig(st_w, keys, dests, preds)
        st_r, moved_r = ref(st_r, keys, dests, preds)
        np.testing.assert_array_equal(np.asarray(moved_w),
                                      np.asarray(moved_r))
        # HOME nodes agree lane-for-lane; slot choice may differ (the
        # windowed path allocates before the wave's GC recycles, the
        # sequential spec interleaves — same latitude as op_window vs its
        # scalar spec)
        locs_w, locs_r = key_locations(st_w), key_locations(st_r)
        assert {k: n for k, (n, _s) in locs_w.items()} \
            == {k: n for k, (n, _s) in locs_r.items()}
        getb = jax.jit(lambda s, k: mgr.runtime.run(
            lambda ss, kk: kv_mig.get_batch(ss, kk), s, k))
        gk = jnp.broadcast_to(jnp.arange(1, 2 * P + 1, dtype=jnp.uint32),
                              (P, 2 * P))
        _s, vw, fw = getb(st_w, gk)
        _s, vr, fr = getb(st_r, gk)
        np.testing.assert_array_equal(np.asarray(fw), np.asarray(fr))
        np.testing.assert_array_equal(np.asarray(vw), np.asarray(vr))

    def test_migrated_store_results_equal_never_migrated(self):
        """The §10.2 transparency contract: after migration, interleaved
        GET/UPDATE/DELETE windows return bit-for-bit the results a
        never-migrated twin returns."""
        st_m = self._seed(kv_mig)
        st_p = self._seed(kv_plain)
        mig = migf(kv_mig)
        keys = jnp.asarray([[1 + p] for p in range(P)], jnp.uint32)
        dests = jnp.asarray([[(p + 1) % P] for p in range(P)], jnp.int32)
        st_m, moved = mig(st_m, keys, dests, jnp.ones((P, 1), bool))
        assert bool(jnp.all(moved))
        step_m, step_p = tstep(kv_mig), tstep(kv_plain)
        rng = np.random.default_rng(11)
        for rnd in range(6):
            w = []
            for p in range(P):
                lane = []
                for _b in range(2):
                    op = int(rng.choice([NOP, GET, UPDATE, DELETE]))
                    k = int(rng.integers(1, 2 * P + 1))
                    lane.append((op, k, v(k, rnd), 0))
                w.append(lane)
            op, key, val, tgt = arrs(w)
            st_m, res_m = step_m(st_m, op, key, val, tgt)
            st_p, res_p = step_p(st_p, op, key, val, tgt)
            for leaf_m, leaf_p in zip(res_m, res_p):
                np.testing.assert_array_equal(np.asarray(leaf_m),
                                              np.asarray(leaf_p),
                                              err_msg=f"round {rnd}")

    def test_move_records_replicate_bitwise(self):
        """MOVE windows ride the ReplicatedLog like any mutation: a
        follower that replays the exported records (targets included)
        converges leaf-for-leaf."""
        m2 = make_manager(P)
        leader = KVStore(None, "mig_leader", m2, **_kw)
        follower = KVStore(None, "mig_follower", m2, **_kw)
        log = ReplicatedLog(None, "mig_log", m2, store=leader, window=2,
                            capacity=2)

        @jax.jit
        def round_(lst, fst, gst, op, key, val, tgt):
            def prog(lst, fst, gst, op, key, val, tgt):
                lst, res = leader.op_window(lst, op, key, val, targets=tgt)
                gst, ok = log.append(gst, op, key, val, targets=tgt)
                gst, fst, _n = log.sync(gst, follower, fst, max_entries=1)
                return lst, fst, gst, res, ok
            return m2.runtime.run(prog, lst, fst, gst, op, key, val, tgt)

        lst, fst, gst = (leader.init_state(), follower.init_state(),
                         log.init_state())
        wins = [
            [[(INSERT, 1 + p, v(1 + p), 0), (INSERT, 1 + P + p,
                                             v(1 + P + p), 0)]
             for p in range(P)],
            [[(MOVE, 1 + p, (0, 0), (p + 1) % P), NOPR] for p in range(P)],
            [[(UPDATE, 1 + p, v(1 + p, 9), 0),
              (DELETE, 1 + P + p, (0, 0), 0)] for p in range(P)],
        ]
        for w in wins:
            op, key, val, tgt = arrs(w)
            lst, fst, gst, res, ok = round_(lst, fst, gst, op, key, val,
                                            tgt)
            assert bool(np.asarray(ok)[0])
        diverged = diverging_leaves(lst, fst)
        assert not diverged, f"diverged on {diverged} across MOVE records"

    def test_fastpath_move_exports_as_nop(self):
        """Regression (code review): a MOVE lane submitted WITHOUT
        targets on a writer-local store is a documented no-op — its
        exported record must be masked to NOP, or a follower (which
        always replays through the placed path) would execute a phantom
        migration the leader never performed."""
        @jax.jit
        def export(op, key, val):
            return mgr.runtime.run(kv_plain.export_window_records,
                                   op, key, val)

        op = jnp.asarray([[MOVE, INSERT]] * P, jnp.int32)
        key = jnp.asarray([[1 + p, 1 + P + p] for p in range(P)],
                          jnp.uint32)
        val = jnp.zeros((P, 2, W), jnp.int32)
        recs = np.asarray(export(op, key, val))      # (P, B, record_width)
        assert (recs[:, 0, 0] == NOP).all(), \
            "fast-path MOVE lanes must export as NOP"
        assert (recs[:, 1, 0] == INSERT).all()

    def test_replication_is_placement_policy_independent(self):
        """Regression: an ``explicit``-placement leader replicated into a
        follower left at the DEFAULT policy must still converge bitwise —
        exported records carry the leader's *resolved* homes, so replay
        never re-derives placement from the follower's own knob."""
        m2 = make_manager(P)
        leader = KVStore(None, "pol_leader", m2, placement="explicit",
                         **_kw)
        follower = KVStore(None, "pol_follower", m2, **_kw)  # 'local'!
        log = ReplicatedLog(None, "pol_log", m2, store=leader, window=2,
                            capacity=2)

        @jax.jit
        def round_(lst, fst, gst, op, key, val, tgt):
            def prog(lst, fst, gst, op, key, val, tgt):
                lst, res = leader.op_window(lst, op, key, val, targets=tgt)
                gst, ok = log.append(gst, op, key, val, targets=tgt)
                gst, fst, _n = log.sync(gst, follower, fst, max_entries=1)
                return lst, fst, gst, res, ok
            return m2.runtime.run(prog, lst, fst, gst, op, key, val, tgt)

        lst, fst, gst = (leader.init_state(), follower.init_state(),
                         log.init_state())
        # inserts homed AWAY from their writers — the case that silently
        # diverged when replay re-applied the follower's local policy
        w = [[(INSERT, 1 + p, v(1 + p), (p + 2) % P),
              (INSERT, 1 + P + p, v(1 + P + p), (p + 1) % P)]
             for p in range(P)]
        op, key, val, tgt = arrs(w)
        lst, fst, gst, res, ok = round_(lst, fst, gst, op, key, val, tgt)
        assert bool(jnp.all(res.found)) and bool(np.asarray(ok)[0])
        diverged = diverging_leaves(lst, fst)
        assert not diverged, \
            f"policy-mismatched follower diverged on {diverged}"
        for k, (node, _s) in key_locations(fst).items():
            want = ((k - 1) % P + 2) % P if k <= P else ((k - 1) % P + 1) % P
            assert node == want, f"follower homed key {k} at {node}"


# ------------------------------------------------------ heat + rebalance
class TestHotTrackerAndRebalance:
    def test_observe_decays_every_window_and_counts_live_lanes(self):
        m2 = make_manager(2)
        hot = HotTracker(None, "hot_unit", m2, nodes=2, slots=2, decay=0.5)
        st = hot.init_state()

        @jax.jit
        def obs(st, nodes, slots, preds):
            return m2.runtime.run(hot.observe, st, nodes, slots, preds)

        nodes = jnp.zeros((2, 2), jnp.int32)
        slots = jnp.asarray([[0, 1], [0, 0]], jnp.int32)
        live = jnp.asarray([[True, True], [False, False]])
        st = obs(st, nodes, slots, live)
        # participant 0 observed rows (0,0) and (0,1); participant 1
        # counted nothing (zero heat is a decay fixed point)
        np.testing.assert_allclose(np.asarray(st.heat[0]), [1, 1, 0, 0])
        np.testing.assert_allclose(np.asarray(st.heat[1]), [0, 0, 0, 0])
        st = obs(st, nodes, slots, live)
        np.testing.assert_allclose(np.asarray(st.heat[0]),
                                   [1.5, 1.5, 0, 0])
        # decay ticks EVERY observed window on EVERY participant — an
        # idle reader's old evidence fades on the same clock as active
        # readers', keeping the dominant-reader argmax scale-consistent
        st = obs(st, nodes, slots, jnp.asarray([[False, False],
                                                [True, False]]))
        np.testing.assert_allclose(np.asarray(st.heat[0]),
                                   [0.75, 0.75, 0, 0])
        np.testing.assert_allclose(np.asarray(st.heat[1]), [1, 0, 0, 0])

    def test_freed_slots_forget_their_heat(self):
        """Regression (code review): a DELETEd or MOVEd-out row's heat
        line is zeroed on every participant, so the slot's next tenant
        starts cold instead of inheriting a dead key's evidence (which
        would trigger unjustified rebalance moves)."""
        m2 = make_manager(P)
        kv = KVStore(None, "loc_forget", m2, slots_per_node=S,
                     value_width=W, num_locks=8, index_capacity=64,
                     track_heat=True)
        step = jax.jit(lambda st, o, k, v_: m2.runtime.run(
            kv.op_window, st, o, k, v_))
        getb = jax.jit(lambda st, k, p: m2.runtime.run(
            lambda s, kk, pp: kv.get_batch(s, kk, pred=pp), st, k, p))
        mig = jax.jit(lambda st, k, d, p: m2.runtime.run(
            kv.migrate_window, st, k, d, p))
        st = kv.init_state()
        w = [[(INSERT, 1 + p, v(1 + p), 0)] for p in range(P)]
        op, key, val, _t = arrs(w)
        st, res = step(st, op, key, val)
        assert bool(jnp.all(res.found))
        locs = key_locations(st)
        lid1 = locs[1][0] * S + locs[1][1]
        lid2 = locs[2][0] * S + locs[2][1]
        # participant 3 reads keys 1 and 2 → both lines heat up
        rk = jnp.broadcast_to(jnp.asarray([1, 2], jnp.uint32), (P, 2))
        pred = jnp.zeros((P, 2), bool).at[3].set(True)
        st, _v, ff = getb(st, rk, pred)
        assert np.asarray(st.heat.heat)[3, lid1] > 0
        assert np.asarray(st.heat.heat)[3, lid2] > 0
        # DELETE key 1, MOVE key 2 → both vacated lines forget, on every
        # participant
        op = jnp.asarray([[DELETE]] + [[NOP]] * (P - 1), jnp.int32)
        st, res = step(st, op, jnp.full((P, 1), 1, jnp.uint32),
                       jnp.zeros((P, 1, W), jnp.int32))
        assert bool(np.asarray(res.found)[0, 0])
        st, moved = mig(st, jnp.full((P, 1), 2, jnp.uint32),
                        jnp.full((P, 1), 3, jnp.int32),
                        jnp.asarray([[True]] + [[False]] * (P - 1)))
        assert bool(np.asarray(moved)[0, 0])
        heat = np.asarray(st.heat.heat)
        assert (heat[:, lid1] == 0).all(), "deleted row's line must forget"
        assert (heat[:, lid2] == 0).all(), "moved-out row's line must forget"

    def test_rebalance_moves_hot_rows_to_dominant_reader(self):
        m2 = make_manager(P)
        kv = KVStore(None, "loc_heat", m2, slots_per_node=2 * P,
                     value_width=W, num_locks=max(8, P * P),
                     index_capacity=256, track_heat=True)
        step = jax.jit(lambda st, o, k, v_, t: m2.runtime.run(
            lambda s, o2, k2, v2, t2: kv.op_window(s, o2, k2, v2,
                                                   targets=t2),
            st, o, k, v_, t))
        getb = jax.jit(lambda st, k, p: m2.runtime.run(
            lambda s, kk, pp: kv.get_batch(s, kk, pred=pp), st, k, p))
        reb = jax.jit(lambda st: m2.runtime.run(
            lambda s: kv.rebalance(s, 2 * P), st))
        reb1 = jax.jit(lambda st: m2.runtime.run(
            lambda s: kv.rebalance(s, 1), st))
        st = kv.init_state()
        # each participant inserts one key writer-locally...
        w = [[(INSERT, 1 + p, v(1 + p), 0)] for p in range(P)]
        op, key, val, tgt = arrs(w)
        st, res = step(st, op, key, val, tgt)
        assert bool(jnp.all(res.found))
        # ...but participant 0 is the dominant reader of ALL of them
        rk = jnp.broadcast_to(jnp.arange(1, P + 1, dtype=jnp.uint32),
                              (P, P))
        pred = jnp.zeros((P, P), bool).at[0].set(True)
        for _ in range(4):
            st, _vv, ff = getb(st, rk, pred)
            assert bool(jnp.all(ff[0]))
        # max_moves is an exact bound even when the P-lane grid rounds
        # past it (code-review regression)
        st, n1 = reb1(st)
        assert int(np.asarray(n1)[0]) == 1
        st, n_moved = reb(st)
        # keys 2..P move to node 0 (key 1 already lives there)
        assert int(np.asarray(n1)[0]) + int(np.asarray(n_moved)[0]) == P - 1
        locs = key_locations(st)
        assert all(locs[k][0] == 0 for k in range(1, P + 1))
        # and the skewed reader's window is now wire-free
        m2.traffic.enable().reset()
        fresh = jax.jit(lambda s, k, p: m2.runtime.run(
            lambda ss, kk, pp: kv.get_batch(ss, kk, pred=pp), s, k, p))
        _s, _vv, ff = fresh(st, rk, pred)
        jax.block_until_ready(ff)
        total = m2.traffic.total_bytes()
        m2.traffic.disable().reset()
        assert bool(jnp.all(ff[0]))
        assert total == 0.0, "rebalanced hot rows must read locally"

    def test_destination_full_migrations_defer_and_retry(self):
        """Regression (§10.3 silent deferral): a rebalance proposal whose
        destination free stack is exhausted used to fail its MOVE
        indistinguishably from "nothing left to move".  Now the deferral
        is counted in ``st.heat.backlog`` (cluster-wide, surfaced by the
        engine as stats()["locality"]["migration_backlog"]) — and because
        the heat evidence persists, the deferred proposal retries and
        executes on the next ``rebalance()`` once the destination frees
        space."""
        m2 = make_manager(P)
        kv = KVStore(None, "loc_backlog", m2, slots_per_node=2,
                     value_width=W, num_locks=8, index_capacity=64,
                     track_heat=True)
        step = jax.jit(lambda st, o, k, v_: m2.runtime.run(
            kv.op_window, st, o, k, v_))
        getb = jax.jit(lambda st, k, p: m2.runtime.run(
            lambda s, kk, pp: kv.get_batch(s, kk, pred=pp), st, k, p))
        reb = jax.jit(lambda st: m2.runtime.run(
            lambda s: kv.rebalance(s, P), st))

        def backlog(st):
            return int(np.asarray(st.heat.backlog)[0])

        st = kv.init_state()
        # node 0 completely full (both its slots), nodes 1/2 hold the
        # keys participant 0 will hammer
        w = [[(INSERT, 1, v(1), 0), (INSERT, 2, v(2), 0)],
             [(INSERT, 11, v(11), 0), NOPR],
             [(INSERT, 12, v(12), 0), NOPR],
             [NOPR, NOPR]]
        op, key, val, _t = arrs(w)
        st, res = step(st, op, key, val)
        assert bool(np.asarray(res.found)[0, 0]) \
            and bool(np.asarray(res.found)[0, 1])
        assert backlog(st) == 0
        # participant 0 becomes the dominant reader of keys 11 and 12
        rk = jnp.broadcast_to(jnp.asarray([11, 12], jnp.uint32), (P, 2))
        pred = jnp.zeros((P, 2), bool).at[0].set(True)
        for _ in range(4):
            st, _vv, ff = getb(st, rk, pred)
            assert bool(jnp.all(ff[0]))
        # both proposals target node 0 — destination full, both deferred
        st, n1 = reb(st)
        assert int(np.asarray(n1)[0]) == 0
        assert backlog(st) == 2, "deferred proposals must be counted"
        locs = key_locations(st)
        assert locs[11][0] == 1 and locs[12][0] == 2
        # free ONE destination slot → exactly one deferral retries
        op = jnp.asarray([[DELETE, NOP]] + [[NOP, NOP]] * (P - 1),
                         jnp.int32)
        st, res = step(st, op, jnp.full((P, 2), 1, jnp.uint32),
                       jnp.zeros((P, 2, W), jnp.int32))
        assert bool(np.asarray(res.found)[0, 0])
        st, n2 = reb(st)
        assert int(np.asarray(n2)[0]) == 1, \
            "a deferred proposal must retry once space frees"
        assert backlog(st) == 1
        # free the second slot → the last deferral drains, backlog zero
        op = jnp.asarray([[DELETE, NOP]] + [[NOP, NOP]] * (P - 1),
                         jnp.int32)
        st, res = step(st, op, jnp.full((P, 2), 2, jnp.uint32),
                       jnp.zeros((P, 2, W), jnp.int32))
        assert bool(np.asarray(res.found)[0, 0])
        st, n3 = reb(st)
        assert int(np.asarray(n3)[0]) == 1
        assert backlog(st) == 0
        locs = key_locations(st)
        assert locs[11][0] == 0 and locs[12][0] == 0, \
            "retried proposals must land at the dominant reader"

    def test_destination_full_spills_to_second_hottest_reader(self):
        """§10.3 backlog spill: a proposal whose dominant destination is
        full no longer just defers — when the SECOND-hottest reader also
        improves locality (heat ≥ min_heat and above the current home's)
        the row moves there in the same ``rebalance()`` call, and the
        backlog drains immediately instead of waiting for the full
        destination to free space."""
        m2 = make_manager(P)
        kv = KVStore(None, "loc_spill", m2, slots_per_node=2,
                     value_width=W, num_locks=8, index_capacity=64,
                     track_heat=True)
        step = jax.jit(lambda st, o, k, v_: m2.runtime.run(
            kv.op_window, st, o, k, v_))
        getb = jax.jit(lambda st, k, p: m2.runtime.run(
            lambda s, kk, pp: kv.get_batch(s, kk, pred=pp), st, k, p))
        reb = jax.jit(lambda st: m2.runtime.run(
            lambda s: kv.rebalance(s, P), st))
        st = kv.init_state()
        # node 0 completely full; key 11 homed (writer-local) at node 2
        w = [[(INSERT, 1, v(1), 0), (INSERT, 2, v(2), 0)],
             [NOPR, NOPR],
             [(INSERT, 11, v(11), 0), NOPR],
             [NOPR, NOPR]]
        op, key, val, _t = arrs(w)
        st, res = step(st, op, key, val)
        assert bool(np.asarray(res.found)[2, 0])
        assert key_locations(st)[11][0] == 2
        # participant 0 dominates reads of key 11, participant 1 is the
        # clear runner-up; the home node (2) never reads it
        rk = jnp.broadcast_to(jnp.asarray([11, 11], jnp.uint32), (P, 2))
        p0 = jnp.zeros((P, 2), bool).at[0].set(True)
        p1 = jnp.zeros((P, 2), bool).at[1].set(True)
        for _ in range(4):
            st, _vv, ff = getb(st, rk, p0)
            assert bool(jnp.all(ff[0]))
        for _ in range(2):
            st, _vv, ff = getb(st, rk, p1)
            assert bool(jnp.all(ff[1]))
        # dominant destination (node 0) is full → the proposal spills to
        # node 1 (second-hottest, has free slots) within ONE rebalance
        st, n1 = reb(st)
        assert int(np.asarray(n1)[0]) == 1, \
            "the spill must execute the blocked proposal"
        assert int(np.asarray(st.heat.backlog)[0]) == 0, \
            "a spilled proposal is not backlog"
        assert key_locations(st)[11][0] == 1, \
            "the row must land at the second-hottest reader"

    def test_rebalance_requires_heat_tracking(self):
        with pytest.raises(ValueError, match="track_heat"):
            mgr.runtime.run(lambda s: kv_plain.rebalance(s, 4),
                            kv_plain.init_state())

    def test_heat_tracked_store_matches_oracle(self):
        m2 = make_manager(P)
        kv = KVStore(None, "loc_heat_oracle", m2, slots_per_node=S,
                     value_width=W, num_locks=8, index_capacity=64,
                     track_heat=True)
        step = jax.jit(lambda st, o, k, v_, t: m2.runtime.run(
            lambda s, o2, k2, v2, t2: kv.op_window(s, o2, k2, v2,
                                                   targets=t2),
            st, o, k, v_, t))
        rng = np.random.default_rng(3)
        oracle = PlacedOracle(lambda p, k, t: p)
        st = kv.init_state()
        for rnd in range(6):
            w = []
            for p in range(P):
                lane = []
                for _b in range(2):
                    op = int(rng.choice([NOP, GET, INSERT, UPDATE,
                                         DELETE]))
                    k = int(rng.integers(1, 9))
                    lane.append((op, k, v(k, rnd), 0))
                w.append(lane)
            op, key, val, tgt = arrs(w)
            st, res = step(st, op, key, val, tgt)
            expect = oracle.apply_window(w)
            for p, lane in enumerate(w):
                for b, op_t in enumerate(lane):
                    o, k = op_t[0], op_t[1]
                    if o == NOP:
                        continue
                    if o == GET:
                        exp = expect[p][b]
                        assert bool(res.found[p][b]) == (exp is not None)
                        if exp is not None:
                            np.testing.assert_array_equal(
                                np.asarray(res.value[p][b]), exp)
                    else:
                        assert bool(res.found[p][b]) == expect[p][b]
