"""Smoke coverage for ``examples/``: every runnable demo imports and runs
headless on a tiny configuration, so the examples cannot rot as the
channel APIs evolve (the PR-5 satellite).

Each example module is loaded from its file path (the directory is not a
package) and its ``main()`` is driven with shrunken knobs — the demos'
own asserts (oracle validation in kvstore_app, replica convergence in
serve_demo's launcher) do the checking.
"""
import importlib.util
import pathlib

import pytest

EXAMPLES = pathlib.Path(__file__).resolve().parent.parent / "examples"


def _load(name):
    spec = importlib.util.spec_from_file_location(
        f"examples_{name}", EXAMPLES / f"{name}.py")
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def test_quickstart_runs_headless(capsys):
    _load("quickstart").main()
    out = capsys.readouterr().out
    assert "quickstart done." in out
    assert "registered channels:" in out


def test_kvstore_app_runs_headless_tiny(capsys):
    _load("kvstore_app").main(keyspace=64, rounds=4)
    out = capsys.readouterr().out
    assert "linearizability holds." in out


def test_serve_demo_runs_headless_tiny(capsys):
    _load("serve_demo").main([
        "--arch", "qwen3-8b", "--smoke", "--requests", "2",
        "--prompt-len", "16", "--gen-len", "4", "--max-batch", "2",
        "--replicas", "1"])
    out = capsys.readouterr().out
    assert "[serve]" in out


@pytest.mark.slow
def test_power_controller_runs_headless():
    _load("power_controller").main()
