"""Unit tests for the core channel objects (paper §4–§5) under the vmap
binding (single device, P simulated participants)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (SST, AtomicVar, Barrier, FenceScope, Manager,
                        OwnedVar, Ringbuffer, SharedQueue, SharedRegion,
                        TicketLock, TicketLockArray, make_manager)
from repro.core.lock import NO_TICKET

P = 4


def run(mgr, fn, *args):
    return mgr.runtime.run(fn, *args)


# ---------------------------------------------------------------- owned_var
class TestOwnedVar:
    def test_push_makes_value_visible_everywhere(self):
        mgr = make_manager(P)
        ov = OwnedVar(None, "v", mgr, owner=2, shape=(3,), dtype=jnp.float32)
        st = ov.init_state()

        def prog(st):
            me = mgr.runtime.my_id()
            st = ov.store_mine(st, jnp.full((3,), 7.5), pred=me == 2)
            st, _ack = ov.push(st)
            val, ok = ov.load(st)
            return st, val, ok

        st, vals, oks = run(mgr, prog, st)
        np.testing.assert_array_equal(np.asarray(vals),
                                      np.full((P, 3), 7.5, np.float32))
        assert np.all(np.asarray(oks))

    def test_pull_refreshes_readers(self):
        mgr = make_manager(P)
        ov = OwnedVar(None, "v", mgr, owner=0, shape=(), dtype=jnp.int32)
        st = ov.init_state()

        def prog(st):
            me = mgr.runtime.my_id()
            # owner stores locally WITHOUT pushing
            st = ov.store_mine(st, jnp.int32(42), pred=me == 0)
            stale = st.cached
            st, _ = ov.pull(st)
            return st, stale, st.cached

        _, stale, fresh = run(mgr, prog, st)
        stale, fresh = np.asarray(stale), np.asarray(fresh)
        assert stale[0] == 42 and np.all(stale[1:] == 0)  # not yet visible
        assert np.all(fresh == 42)                        # visible after pull

    def test_checksum_detects_torn_value(self):
        mgr = make_manager(P)
        ov = OwnedVar(None, "v", mgr, owner=0, shape=(4,), dtype=jnp.int32)
        st = ov.init_state()
        # inject a tear: corrupt one word of participant 1's cached copy
        buf = np.asarray(st.cached).copy()
        buf[1, 2] = 999
        st = st._replace(cached=jnp.asarray(buf))

        def prog(st):
            return ov.load(st)

        _vals, oks = run(mgr, prog, st)
        oks = np.asarray(oks)
        assert not oks[1] and oks[0] and np.all(oks[2:])


# ---------------------------------------------------------------- atomic_var
class TestAtomicVar:
    def test_concurrent_fetch_add_serializes_in_participant_order(self):
        mgr = make_manager(P)
        av = AtomicVar(None, "a", mgr, host=1, dtype=jnp.int32)
        st = av.init_state(100)

        def prog(st):
            me = mgr.runtime.my_id()
            st, old, _ = av.fetch_add(st, me + 1, pred=True)  # adds 1..P
            return st, old, av.load_cached(st)

        st, olds, cached = run(mgr, prog, st)
        olds = np.asarray(olds)
        # participant i's old value = 100 + sum of amounts of lower ids
        expect = [100, 101, 103, 106]
        np.testing.assert_array_equal(olds, expect)
        np.testing.assert_array_equal(np.asarray(cached), [110] * P)

    def test_fetch_add_respects_pred(self):
        mgr = make_manager(P)
        av = AtomicVar(None, "a", mgr, host=0, dtype=jnp.int32)
        st = av.init_state(0)

        def prog(st):
            me = mgr.runtime.my_id()
            st, old, _ = av.fetch_add(st, 5, pred=(me % 2) == 0)
            return st, old

        st, olds = run(mgr, prog, st)
        np.testing.assert_array_equal(np.asarray(olds), [0, 0, 5, 0])
        np.testing.assert_array_equal(np.asarray(st.official), [10] * P)

    def test_cas_lowest_contender_wins(self):
        mgr = make_manager(P)
        av = AtomicVar(None, "a", mgr, host=0, dtype=jnp.int32)
        st = av.init_state(7)

        def prog(st):
            me = mgr.runtime.my_id()
            st, old, ok, _ = av.compare_swap(st, 7, 100 + me,
                                             pred=me >= 1)
            return st, old, ok

        st, olds, oks = run(mgr, prog, st)
        np.testing.assert_array_equal(np.asarray(oks),
                                      [False, True, False, False])
        np.testing.assert_array_equal(np.asarray(st.official), [101] * P)
        np.testing.assert_array_equal(np.asarray(olds), [7] * P)


# ---------------------------------------------------------------------- SST
class TestSST:
    def test_push_broadcast_exchanges_rows(self):
        mgr = make_manager(P)
        sst = SST(None, "s", mgr, shape=(2,), dtype=jnp.int32)
        st = sst.init_state()

        def prog(st):
            me = mgr.runtime.my_id()
            st = sst.store_mine(st, jnp.stack([me, me * 10]))
            st, ack = sst.push_broadcast(st)
            return st, sst.rows(st)

        _, tables = run(mgr, prog, st)
        tables = np.asarray(tables)  # (P, P, 2)
        for viewer in range(P):
            for row in range(P):
                np.testing.assert_array_equal(tables[viewer, row],
                                              [row, row * 10])

    def test_sst_composes_from_owned_vars(self):
        mgr = make_manager(P)
        sst = SST(None, "s", mgr, shape=(), dtype=jnp.int32)
        # namespacing: P owned_var sub-channels exist under "s/"
        for i in range(P):
            assert f"s/ov{i}" in mgr.channels
        assert mgr.channels["s/ov0"].owner == 0


# ------------------------------------------------------------------- barrier
class TestBarrier:
    def test_all_participants_advance_together(self):
        mgr = make_manager(P)
        bar = Barrier(None, "bar", mgr)
        st = bar.init_state()

        def prog(st):
            st = bar.wait(st)
            st = bar.wait(st)
            return st

        st = run(mgr, prog, st)
        np.testing.assert_array_equal(np.asarray(st.count), [2] * P)
        # every participant observed everyone's count
        rows = np.asarray(st.sst.cached)
        assert np.all(rows >= 2)

    def test_expect_num_mismatch_raises(self):
        mgr = make_manager(P)
        with pytest.raises(ValueError, match="join would never complete"):
            Barrier(None, "bar", mgr, expect_num=P + 1)


# -------------------------------------------------------------- shared_region
class TestSharedRegion:
    def test_remote_read_ring(self):
        mgr = make_manager(P)
        reg = SharedRegion(None, "r", mgr, slots=3, item_shape=(2,),
                           dtype=jnp.float32)
        st = reg.init_state()
        # participant p's slot 1 holds [p, p+0.5]
        buf = np.zeros((P, 3, 2), np.float32)
        for p in range(P):
            buf[p, 1] = [p, p + 0.5]
        st = st._replace(buf=jnp.asarray(buf))

        def prog(st):
            me = mgr.runtime.my_id()
            tgt = (me + 1) % P
            val, _ack = reg.read(st, tgt, 1)
            return val

        vals = np.asarray(run(mgr, prog, st))
        for p in range(P):
            np.testing.assert_allclose(vals[p], [(p + 1) % P,
                                                 (p + 1) % P + 0.5])

    def test_remote_write_lands_at_target(self):
        mgr = make_manager(P)
        reg = SharedRegion(None, "r", mgr, slots=P, item_shape=(),
                           dtype=jnp.int32)
        st = reg.init_state()

        def prog(st):
            me = mgr.runtime.my_id()
            tgt = (me + 1) % P
            st, _ack = reg.write(st, tgt, me, 100 + me)
            return st

        st = run(mgr, prog, st)
        buf = np.asarray(st.buf)  # (P, P)
        for writer in range(P):
            target = (writer + 1) % P
            assert buf[target, writer] == 100 + writer

    def test_batch_read_write_roundtrip(self):
        mgr = make_manager(P)
        reg = SharedRegion(None, "r", mgr, slots=4, item_shape=(),
                           dtype=jnp.int32)
        st = reg.init_state()

        def prog(st):
            me = mgr.runtime.my_id()
            tgts = jnp.array([(me + 1) % P, (me + 2) % P], jnp.int32)
            idxs = jnp.array([0, 1], jnp.int32)
            vals = jnp.array([10 * me, 10 * me + 1], jnp.int32)
            st, _ = reg.write_batch(st, tgts, idxs, vals)
            got, _ = reg.read_batch(st, tgts, idxs)
            return st, got

        st, got = run(mgr, prog, st)
        got = np.asarray(got)
        for p in range(P):
            np.testing.assert_array_equal(got[p], [10 * p, 10 * p + 1])


# ---------------------------------------------------------------- ticket lock
class TestTicketLock:
    def test_fifo_service_and_mutual_exclusion(self):
        mgr = make_manager(P)
        lk = TicketLock(None, "l", mgr, host=0)
        st = lk.init_state()

        def prog(st):
            me = mgr.runtime.my_id()
            st, ticket = lk.acquire(st, want=True)
            holder_log = []
            for _round in range(P):
                holds = lk.holds(st, ticket)
                holder_log.append(holds)
                st = lk.release(st, holds, fence_scope=FenceScope.PAIR)
            return st, ticket, jnp.stack(holder_log)

        st, tickets, logs = run(mgr, prog, st)
        tickets, logs = np.asarray(tickets), np.asarray(logs)  # (P,), (P, P)
        np.testing.assert_array_equal(sorted(tickets), range(P))
        # exactly one holder per round; participant order (ticket i at round i)
        for rnd in range(P):
            holders = np.nonzero(logs[:, rnd])[0]
            assert len(holders) == 1
            assert tickets[holders[0]] == rnd

    def test_lock_array_independent_stripes(self):
        mgr = make_manager(P)
        la = TicketLockArray(None, "locks", mgr, num_locks=2)
        st = la.init_state()

        def prog(st):
            me = mgr.runtime.my_id()
            lock_id = me % 2
            st, ticket = la.acquire(st, lock_id, want=True)
            h0 = la.holds(st, lock_id, ticket)
            st = la.release(st, lock_id, h0)
            h1 = la.holds(st, lock_id, ticket)
            st = la.release(st, lock_id, h1)
            return st, ticket, h0, h1

        st, tickets, h0, h1 = run(mgr, prog, st)
        tickets = np.asarray(tickets)
        # two participants per stripe; tickets 0,1 within each
        np.testing.assert_array_equal(tickets, [0, 0, 1, 1])
        np.testing.assert_array_equal(np.asarray(h0),
                                      [True, True, False, False])
        np.testing.assert_array_equal(np.asarray(h1),
                                      [False, False, True, True])


# ----------------------------------------------------------------- ringbuffer
class TestRingbuffer:
    def test_broadcast_in_order(self):
        mgr = make_manager(P)
        rb = Ringbuffer(None, "rb", mgr, owner=0, capacity=4, width=2)
        st = rb.init_state()

        def prog(st):
            me = mgr.runtime.my_id()
            got_msgs, got_flags = [], []
            for k in range(3):
                msg = jnp.array([k + 1, (k + 1) * 10], jnp.int32)
                st, sent, _ = rb.send(st, msg, 2, pred=me == 0)
                st, m, _l, got = rb.recv_one(st)
                got_msgs.append(m)
                got_flags.append(got)
            return st, jnp.stack(got_msgs), jnp.stack(got_flags)

        st, msgs, flags = run(mgr, prog, st)
        msgs, flags = np.asarray(msgs), np.asarray(flags)
        assert np.all(flags)
        for k in range(3):
            np.testing.assert_array_equal(msgs[:, k],
                                          np.tile([k + 1, (k + 1) * 10],
                                                  (P, 1)))

    def test_full_ring_blocks_sender_until_acks(self):
        mgr = make_manager(P)
        rb = Ringbuffer(None, "rb", mgr, owner=0, capacity=2, width=1)
        st = rb.init_state()

        def prog(st):
            me = mgr.runtime.my_id()
            sents = []
            for k in range(3):  # 3rd send must fail (no recv acks)
                st, sent, _ = rb.send(st, jnp.array([k], jnp.int32), 1,
                                      pred=me == 0)
                sents.append(sent)
            # drain one, then send succeeds again
            st, _m, _l, _got = rb.recv_one(st)
            st, sent_after, _ = rb.send(st, jnp.array([9], jnp.int32), 1,
                                        pred=me == 0)
            return st, jnp.stack(sents), sent_after

        st, sents, sent_after = run(mgr, prog, st)
        sents = np.asarray(sents)
        assert np.all(sents[0, :2]) and not sents[0, 2]
        assert np.asarray(sent_after)[0]


# ---------------------------------------------------------------- shared queue
class TestSharedQueue:
    def test_concurrent_enqueue_dequeue_fifo(self):
        mgr = make_manager(P)
        q = SharedQueue(None, "q", mgr, slots_per_node=2, width=1)
        st = q.init_state()

        def prog(st):
            me = mgr.runtime.my_id()
            st, ok1 = q.enqueue(st, jnp.array([100 + me], jnp.int32))
            st, val, ok2 = q.dequeue(st)
            return st, ok1, val, ok2

        st, ok1, vals, ok2 = run(mgr, prog, st)
        assert np.all(np.asarray(ok1)) and np.all(np.asarray(ok2))
        # FIFO: dequeue ticket i returns enqueue ticket i (participant order)
        np.testing.assert_array_equal(np.asarray(vals)[:, 0],
                                      [100, 101, 102, 103])

    def test_dequeue_empty_fails_cleanly(self):
        mgr = make_manager(P)
        q = SharedQueue(None, "q", mgr, slots_per_node=1, width=1)
        st = q.init_state()

        def prog(st):
            st, _v, ok = q.dequeue(st)
            return st, ok

        _st, ok = run(mgr, prog, st)
        assert not np.any(np.asarray(ok))

    def test_flow_control_rejects_overflow(self):
        mgr = make_manager(P)
        q = SharedQueue(None, "q", mgr, slots_per_node=1, width=1)  # cap 4
        st = q.init_state()

        def prog(st):
            me = mgr.runtime.my_id()
            st, ok1 = q.enqueue(st, jnp.array([me], jnp.int32))
            st, ok2 = q.enqueue(st, jnp.array([me + 10], jnp.int32))
            return st, ok1, ok2

        _st, ok1, ok2 = run(mgr, prog, st)
        assert np.all(np.asarray(ok1))
        assert not np.any(np.asarray(ok2))  # capacity P already used


# --------------------------------------------------------------- manager/fences
class TestManagerAndFences:
    def test_channel_name_collision_rejected(self):
        mgr = make_manager(P)
        OwnedVar(None, "x", mgr, owner=0)
        with pytest.raises(ValueError, match="collision"):
            OwnedVar(None, "x", mgr, owner=1)

    def test_memory_ledger_accounts_regions(self):
        mgr = make_manager(P)
        SharedRegion(None, "r", mgr, slots=10, item_shape=(4,),
                     dtype=jnp.float32)
        assert mgr.memory_ledger_bytes() == 10 * 4 * 4

    def test_fence_scopes_tracked(self):
        mgr = make_manager(P)
        ov = OwnedVar(None, "v", mgr, owner=0, shape=(2,), dtype=jnp.float32)
        st = ov.init_state()

        def prog(st):
            with mgr.tracking():
                st2, _ = ov.push(st)
                out = mgr.fence(st2.cached, scope=FenceScope.GLOBAL)
            return out

        out = run(mgr, prog, st)
        assert out.shape == (P, 2)
        assert mgr.fence_counts[FenceScope.GLOBAL] >= 1

    def test_pair_fence_keeps_other_ops_outstanding(self):
        mgr = make_manager(P)
        ov0 = OwnedVar(None, "a", mgr, owner=0, shape=(), dtype=jnp.float32)
        ov1 = OwnedVar(None, "b", mgr, owner=1, shape=(), dtype=jnp.float32)
        s0, s1 = ov0.init_state(), ov1.init_state()

        def prog(s0, s1):
            with mgr.tracking():
                s0b, _ = ov0.pull(s0)   # targets peer 0
                s1b, _ = ov1.pull(s1)   # targets peer 1
                _ = mgr.fence(s0b.cached, scope=FenceScope.PAIR, peer=0)
                still_out = mgr.outstanding()
                assert len(still_out.descs) == 1  # peer-1 op still pending
                assert still_out.descs[0].peers == (1,)
            return s0b.cached

        run(mgr, prog, s0, s1)


# ------------------------------------------------- coalesced read verbs (§8.1)
class TestCoalescedReads:
    """remote_read_coalesced / remote_read_batch(coalesce=True): modeled
    wire bytes scale with *unique* enabled remote (target, index) pairs —
    duplicates fan out locally — and results stay bitwise-identical to the
    uncoalesced verb on every lane pattern."""

    ITEM = 8            # item_shape=(2,) int32 → 8 payload bytes per row
    R = 6

    def _setup(self, tag):
        mgr = make_manager(P)
        reg = SharedRegion(None, f"coal_{tag}", mgr, slots=4,
                           item_shape=(2,), dtype=jnp.int32)
        st = reg.init_state()
        # distinct, recognizable rows: row[i] at participant p = (100p+i)·(1, 10)
        buf = (np.arange(P)[:, None, None] * 100
               + np.arange(4)[None, :, None]) * np.array([1, 10])[None, None, :]
        st = st._replace(buf=jnp.asarray(buf, jnp.int32))
        return mgr, reg, st

    def _read(self, mgr, reg, st, tgts, idxs, preds=None, coalesce=True):
        """tgts/idxs/preds: (P, R) per-participant lanes.  Returns
        (values (P, R, 2), modeled wire bytes)."""
        tp = jnp.asarray(tgts, jnp.int32)
        ip = jnp.asarray(idxs, jnp.int32)
        pp = None if preds is None else jnp.asarray(preds)
        mgr.traffic.enable().reset()

        def prog(st, t, i, *p):
            got, _ = reg.read_batch(st, t, i, preds=p[0] if p else None,
                                    coalesce=coalesce)
            return got

        args = (st, tp, ip) + ((pp,) if pp is not None else ())
        got = run(mgr, prog, *args)
        jax.block_until_ready(got)
        total = mgr.traffic.total_bytes()
        mgr.traffic.disable().reset()
        return np.asarray(got), total

    def _expect(self, tgts, idxs, preds=None):
        """Reference values + unique/total remote lane counts (numpy)."""
        tgts, idxs = np.asarray(tgts), np.asarray(idxs)
        preds = np.ones_like(tgts, bool) if preds is None else np.asarray(preds)
        vals = np.zeros(tgts.shape + (2,), np.int64)
        uniq = total = 0
        for p in range(P):
            seen = set()
            for r in range(tgts.shape[1]):
                if not preds[p, r]:
                    continue
                t, i = int(tgts[p, r]), int(idxs[p, r])
                vals[p, r] = (100 * t + i) * np.array([1, 10])
                if t != p:
                    total += 1
                    if (t, i) not in seen:
                        seen.add((t, i))
                        uniq += 1
        return vals, uniq, total

    def test_duplicate_heavy_lanes_pay_unique_rows_only(self):
        mgr, reg, st = self._setup("dup")
        tgts = np.stack([np.full(self.R, (p + 1) % P) for p in range(P)])
        idxs = np.zeros((P, self.R), np.int64)      # all lanes, one hot row
        vals, uniq, total = self._expect(tgts, idxs)
        got_c, bytes_c = self._read(mgr, reg, st, tgts, idxs, coalesce=True)
        got_d, bytes_d = self._read(mgr, reg, st, tgts, idxs, coalesce=False)
        np.testing.assert_array_equal(got_c, vals)
        np.testing.assert_array_equal(got_c, got_d)    # bitwise-identical
        assert uniq == P and total == P * self.R
        assert bytes_c == 2 * self.ITEM * uniq         # one row per part.
        assert bytes_d == 2 * self.ITEM * total        # R rows per part.

    def test_all_self_lanes_cost_zero(self):
        mgr, reg, st = self._setup("self")
        tgts = np.repeat(np.arange(P)[:, None], self.R, axis=1)
        idxs = np.tile(np.arange(self.R) % 4, (P, 1))
        vals, uniq, total = self._expect(tgts, idxs)
        assert uniq == total == 0
        got_c, bytes_c = self._read(mgr, reg, st, tgts, idxs, coalesce=True)
        np.testing.assert_array_equal(got_c, vals)
        assert bytes_c == 0.0

    def test_all_unique_lanes_match_uncoalesced_cost(self):
        mgr, reg, st = self._setup("uniq")
        # R=4 distinct (target, index) pairs per participant, all remote
        tgts = np.stack([[(p + 1) % P, (p + 1) % P,
                          (p + 2) % P, (p + 3) % P] for p in range(P)])
        idxs = np.tile([0, 1, 0, 2], (P, 1))
        vals, uniq, total = self._expect(tgts, idxs)
        assert uniq == total == 4 * P
        got_c, bytes_c = self._read(mgr, reg, st, tgts, idxs, coalesce=True)
        got_d, bytes_d = self._read(mgr, reg, st, tgts, idxs, coalesce=False)
        np.testing.assert_array_equal(got_c, vals)
        np.testing.assert_array_equal(got_c, got_d)
        assert bytes_c == bytes_d == 2 * self.ITEM * uniq

    def test_disabled_duplicates_neither_lead_nor_count(self):
        mgr, reg, st = self._setup("pred")
        tgts = np.stack([np.full(self.R, (p + 1) % P) for p in range(P)])
        idxs = np.tile(np.arange(self.R) % 2, (P, 1))  # two hot rows
        preds = np.tile([False, True, True, False, True, False], (P, 1))
        vals, uniq, total = self._expect(tgts, idxs, preds)
        got_c, bytes_c = self._read(mgr, reg, st, tgts, idxs, preds, True)
        np.testing.assert_array_equal(got_c, vals)     # disabled → zeros
        assert bytes_c == 2 * self.ITEM * uniq
        assert uniq == 2 * P                           # rows 0 and 1 each

    @pytest.mark.parametrize("seed", range(3))
    def test_random_patterns_bitwise_equal_and_cheaper(self, seed):
        rng = np.random.default_rng(seed)
        mgr, reg, st = self._setup(f"rand{seed}")
        tgts = rng.integers(0, P, (P, self.R))
        idxs = rng.integers(0, 4, (P, self.R))
        preds = rng.random((P, self.R)) < 0.8
        vals, uniq, total = self._expect(tgts, idxs, preds)
        got_c, bytes_c = self._read(mgr, reg, st, tgts, idxs, preds, True)
        got_d, bytes_d = self._read(mgr, reg, st, tgts, idxs, preds, False)
        np.testing.assert_array_equal(got_c, got_d)
        np.testing.assert_array_equal(got_c, vals)
        assert bytes_c == 2 * self.ITEM * uniq
        assert bytes_d == 2 * self.ITEM * total
        assert bytes_c <= bytes_d
