"""Unit tests for the core channel objects (paper §4–§5) under the vmap
binding (single device, P simulated participants)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (SST, AtomicVar, Barrier, FenceScope, Manager,
                        OwnedVar, Ringbuffer, SharedQueue, SharedRegion,
                        TicketLock, TicketLockArray, make_manager)
from repro.core.lock import NO_TICKET

P = 4


def run(mgr, fn, *args):
    return mgr.runtime.run(fn, *args)


# ---------------------------------------------------------------- owned_var
class TestOwnedVar:
    def test_push_makes_value_visible_everywhere(self):
        mgr = make_manager(P)
        ov = OwnedVar(None, "v", mgr, owner=2, shape=(3,), dtype=jnp.float32)
        st = ov.init_state()

        def prog(st):
            me = mgr.runtime.my_id()
            st = ov.store_mine(st, jnp.full((3,), 7.5), pred=me == 2)
            st, _ack = ov.push(st)
            val, ok = ov.load(st)
            return st, val, ok

        st, vals, oks = run(mgr, prog, st)
        np.testing.assert_array_equal(np.asarray(vals),
                                      np.full((P, 3), 7.5, np.float32))
        assert np.all(np.asarray(oks))

    def test_pull_refreshes_readers(self):
        mgr = make_manager(P)
        ov = OwnedVar(None, "v", mgr, owner=0, shape=(), dtype=jnp.int32)
        st = ov.init_state()

        def prog(st):
            me = mgr.runtime.my_id()
            # owner stores locally WITHOUT pushing
            st = ov.store_mine(st, jnp.int32(42), pred=me == 0)
            stale = st.cached
            st, _ = ov.pull(st)
            return st, stale, st.cached

        _, stale, fresh = run(mgr, prog, st)
        stale, fresh = np.asarray(stale), np.asarray(fresh)
        assert stale[0] == 42 and np.all(stale[1:] == 0)  # not yet visible
        assert np.all(fresh == 42)                        # visible after pull

    def test_checksum_detects_torn_value(self):
        mgr = make_manager(P)
        ov = OwnedVar(None, "v", mgr, owner=0, shape=(4,), dtype=jnp.int32)
        st = ov.init_state()
        # inject a tear: corrupt one word of participant 1's cached copy
        buf = np.asarray(st.cached).copy()
        buf[1, 2] = 999
        st = st._replace(cached=jnp.asarray(buf))

        def prog(st):
            return ov.load(st)

        _vals, oks = run(mgr, prog, st)
        oks = np.asarray(oks)
        assert not oks[1] and oks[0] and np.all(oks[2:])


# ---------------------------------------------------------------- atomic_var
class TestAtomicVar:
    def test_concurrent_fetch_add_serializes_in_participant_order(self):
        mgr = make_manager(P)
        av = AtomicVar(None, "a", mgr, host=1, dtype=jnp.int32)
        st = av.init_state(100)

        def prog(st):
            me = mgr.runtime.my_id()
            st, old, _ = av.fetch_add(st, me + 1, pred=True)  # adds 1..P
            return st, old, av.load_cached(st)

        st, olds, cached = run(mgr, prog, st)
        olds = np.asarray(olds)
        # participant i's old value = 100 + sum of amounts of lower ids
        expect = [100, 101, 103, 106]
        np.testing.assert_array_equal(olds, expect)
        np.testing.assert_array_equal(np.asarray(cached), [110] * P)

    def test_fetch_add_respects_pred(self):
        mgr = make_manager(P)
        av = AtomicVar(None, "a", mgr, host=0, dtype=jnp.int32)
        st = av.init_state(0)

        def prog(st):
            me = mgr.runtime.my_id()
            st, old, _ = av.fetch_add(st, 5, pred=(me % 2) == 0)
            return st, old

        st, olds = run(mgr, prog, st)
        np.testing.assert_array_equal(np.asarray(olds), [0, 0, 5, 0])
        np.testing.assert_array_equal(np.asarray(st.official), [10] * P)

    def test_cas_lowest_contender_wins(self):
        mgr = make_manager(P)
        av = AtomicVar(None, "a", mgr, host=0, dtype=jnp.int32)
        st = av.init_state(7)

        def prog(st):
            me = mgr.runtime.my_id()
            st, old, ok, _ = av.compare_swap(st, 7, 100 + me,
                                             pred=me >= 1)
            return st, old, ok

        st, olds, oks = run(mgr, prog, st)
        np.testing.assert_array_equal(np.asarray(oks),
                                      [False, True, False, False])
        np.testing.assert_array_equal(np.asarray(st.official), [101] * P)
        np.testing.assert_array_equal(np.asarray(olds), [7] * P)


# ---------------------------------------------------------------------- SST
class TestSST:
    def test_push_broadcast_exchanges_rows(self):
        mgr = make_manager(P)
        sst = SST(None, "s", mgr, shape=(2,), dtype=jnp.int32)
        st = sst.init_state()

        def prog(st):
            me = mgr.runtime.my_id()
            st = sst.store_mine(st, jnp.stack([me, me * 10]))
            st, ack = sst.push_broadcast(st)
            return st, sst.rows(st)

        _, tables = run(mgr, prog, st)
        tables = np.asarray(tables)  # (P, P, 2)
        for viewer in range(P):
            for row in range(P):
                np.testing.assert_array_equal(tables[viewer, row],
                                              [row, row * 10])

    def test_sst_composes_from_owned_vars(self):
        mgr = make_manager(P)
        sst = SST(None, "s", mgr, shape=(), dtype=jnp.int32)
        # namespacing: P owned_var sub-channels exist under "s/"
        for i in range(P):
            assert f"s/ov{i}" in mgr.channels
        assert mgr.channels["s/ov0"].owner == 0


# ------------------------------------------------------------------- barrier
class TestBarrier:
    def test_all_participants_advance_together(self):
        mgr = make_manager(P)
        bar = Barrier(None, "bar", mgr)
        st = bar.init_state()

        def prog(st):
            st = bar.wait(st)
            st = bar.wait(st)
            return st

        st = run(mgr, prog, st)
        np.testing.assert_array_equal(np.asarray(st.count), [2] * P)
        # every participant observed everyone's count
        rows = np.asarray(st.sst.cached)
        assert np.all(rows >= 2)

    def test_expect_num_mismatch_raises(self):
        mgr = make_manager(P)
        with pytest.raises(ValueError, match="join would never complete"):
            Barrier(None, "bar", mgr, expect_num=P + 1)


# -------------------------------------------------------------- shared_region
class TestSharedRegion:
    def test_remote_read_ring(self):
        mgr = make_manager(P)
        reg = SharedRegion(None, "r", mgr, slots=3, item_shape=(2,),
                           dtype=jnp.float32)
        st = reg.init_state()
        # participant p's slot 1 holds [p, p+0.5]
        buf = np.zeros((P, 3, 2), np.float32)
        for p in range(P):
            buf[p, 1] = [p, p + 0.5]
        st = st._replace(buf=jnp.asarray(buf))

        def prog(st):
            me = mgr.runtime.my_id()
            tgt = (me + 1) % P
            val, _ack = reg.read(st, tgt, 1)
            return val

        vals = np.asarray(run(mgr, prog, st))
        for p in range(P):
            np.testing.assert_allclose(vals[p], [(p + 1) % P,
                                                 (p + 1) % P + 0.5])

    def test_remote_write_lands_at_target(self):
        mgr = make_manager(P)
        reg = SharedRegion(None, "r", mgr, slots=P, item_shape=(),
                           dtype=jnp.int32)
        st = reg.init_state()

        def prog(st):
            me = mgr.runtime.my_id()
            tgt = (me + 1) % P
            st, _ack = reg.write(st, tgt, me, 100 + me)
            return st

        st = run(mgr, prog, st)
        buf = np.asarray(st.buf)  # (P, P)
        for writer in range(P):
            target = (writer + 1) % P
            assert buf[target, writer] == 100 + writer

    def test_batch_read_write_roundtrip(self):
        mgr = make_manager(P)
        reg = SharedRegion(None, "r", mgr, slots=4, item_shape=(),
                           dtype=jnp.int32)
        st = reg.init_state()

        def prog(st):
            me = mgr.runtime.my_id()
            tgts = jnp.array([(me + 1) % P, (me + 2) % P], jnp.int32)
            idxs = jnp.array([0, 1], jnp.int32)
            vals = jnp.array([10 * me, 10 * me + 1], jnp.int32)
            st, _ = reg.write_batch(st, tgts, idxs, vals)
            got, _ = reg.read_batch(st, tgts, idxs)
            return st, got

        st, got = run(mgr, prog, st)
        got = np.asarray(got)
        for p in range(P):
            np.testing.assert_array_equal(got[p], [10 * p, 10 * p + 1])


# ---------------------------------------------------------------- ticket lock
class TestTicketLock:
    def test_fifo_service_and_mutual_exclusion(self):
        mgr = make_manager(P)
        lk = TicketLock(None, "l", mgr, host=0)
        st = lk.init_state()

        def prog(st):
            me = mgr.runtime.my_id()
            st, ticket = lk.acquire(st, want=True)
            holder_log = []
            for _round in range(P):
                holds = lk.holds(st, ticket)
                holder_log.append(holds)
                st = lk.release(st, holds, fence_scope=FenceScope.PAIR)
            return st, ticket, jnp.stack(holder_log)

        st, tickets, logs = run(mgr, prog, st)
        tickets, logs = np.asarray(tickets), np.asarray(logs)  # (P,), (P, P)
        np.testing.assert_array_equal(sorted(tickets), range(P))
        # exactly one holder per round; participant order (ticket i at round i)
        for rnd in range(P):
            holders = np.nonzero(logs[:, rnd])[0]
            assert len(holders) == 1
            assert tickets[holders[0]] == rnd

    def test_lock_array_independent_stripes(self):
        mgr = make_manager(P)
        la = TicketLockArray(None, "locks", mgr, num_locks=2)
        st = la.init_state()

        def prog(st):
            me = mgr.runtime.my_id()
            lock_id = me % 2
            st, ticket = la.acquire(st, lock_id, want=True)
            h0 = la.holds(st, lock_id, ticket)
            st = la.release(st, lock_id, h0)
            h1 = la.holds(st, lock_id, ticket)
            st = la.release(st, lock_id, h1)
            return st, ticket, h0, h1

        st, tickets, h0, h1 = run(mgr, prog, st)
        tickets = np.asarray(tickets)
        # two participants per stripe; tickets 0,1 within each
        np.testing.assert_array_equal(tickets, [0, 0, 1, 1])
        np.testing.assert_array_equal(np.asarray(h0),
                                      [True, True, False, False])
        np.testing.assert_array_equal(np.asarray(h1),
                                      [False, False, True, True])


# ----------------------------------------------------------------- ringbuffer
class TestRingbuffer:
    def test_broadcast_in_order(self):
        mgr = make_manager(P)
        rb = Ringbuffer(None, "rb", mgr, owner=0, capacity=4, width=2)
        st = rb.init_state()

        def prog(st):
            me = mgr.runtime.my_id()
            got_msgs, got_flags = [], []
            for k in range(3):
                msg = jnp.array([k + 1, (k + 1) * 10], jnp.int32)
                st, sent, _ = rb.send(st, msg, 2, pred=me == 0)
                st, m, _l, got = rb.recv_one(st)
                got_msgs.append(m)
                got_flags.append(got)
            return st, jnp.stack(got_msgs), jnp.stack(got_flags)

        st, msgs, flags = run(mgr, prog, st)
        msgs, flags = np.asarray(msgs), np.asarray(flags)
        assert np.all(flags)
        for k in range(3):
            np.testing.assert_array_equal(msgs[:, k],
                                          np.tile([k + 1, (k + 1) * 10],
                                                  (P, 1)))

    def test_full_ring_blocks_sender_until_acks(self):
        mgr = make_manager(P)
        rb = Ringbuffer(None, "rb", mgr, owner=0, capacity=2, width=1)
        st = rb.init_state()

        def prog(st):
            me = mgr.runtime.my_id()
            sents = []
            for k in range(3):  # 3rd send must fail (no recv acks)
                st, sent, _ = rb.send(st, jnp.array([k], jnp.int32), 1,
                                      pred=me == 0)
                sents.append(sent)
            # drain one, then send succeeds again
            st, _m, _l, _got = rb.recv_one(st)
            st, sent_after, _ = rb.send(st, jnp.array([9], jnp.int32), 1,
                                        pred=me == 0)
            return st, jnp.stack(sents), sent_after

        st, sents, sent_after = run(mgr, prog, st)
        sents = np.asarray(sents)
        assert np.all(sents[0, :2]) and not sents[0, 2]
        assert np.asarray(sent_after)[0]


# ---------------------------------------------------------------- shared queue
class TestSharedQueue:
    def test_concurrent_enqueue_dequeue_fifo(self):
        mgr = make_manager(P)
        q = SharedQueue(None, "q", mgr, slots_per_node=2, width=1)
        st = q.init_state()

        def prog(st):
            me = mgr.runtime.my_id()
            st, ok1 = q.enqueue(st, jnp.array([100 + me], jnp.int32))
            st, val, ok2 = q.dequeue(st)
            return st, ok1, val, ok2

        st, ok1, vals, ok2 = run(mgr, prog, st)
        assert np.all(np.asarray(ok1)) and np.all(np.asarray(ok2))
        # FIFO: dequeue ticket i returns enqueue ticket i (participant order)
        np.testing.assert_array_equal(np.asarray(vals)[:, 0],
                                      [100, 101, 102, 103])

    def test_dequeue_empty_fails_cleanly(self):
        mgr = make_manager(P)
        q = SharedQueue(None, "q", mgr, slots_per_node=1, width=1)
        st = q.init_state()

        def prog(st):
            st, _v, ok = q.dequeue(st)
            return st, ok

        _st, ok = run(mgr, prog, st)
        assert not np.any(np.asarray(ok))

    def test_flow_control_rejects_overflow(self):
        mgr = make_manager(P)
        q = SharedQueue(None, "q", mgr, slots_per_node=1, width=1)  # cap 4
        st = q.init_state()

        def prog(st):
            me = mgr.runtime.my_id()
            st, ok1 = q.enqueue(st, jnp.array([me], jnp.int32))
            st, ok2 = q.enqueue(st, jnp.array([me + 10], jnp.int32))
            return st, ok1, ok2

        _st, ok1, ok2 = run(mgr, prog, st)
        assert np.all(np.asarray(ok1))
        assert not np.any(np.asarray(ok2))  # capacity P already used


# ------------------------------------------------ windowed streaming (§9.1)
class QueueWindowOracle:
    """Sequential FIFO oracle for windowed queue rounds: grants resolve in
    (participant, lane) lexicographic rank order against the space/items
    available at round start — rejections are always a rank suffix."""

    def __init__(self, capacity):
        self.capacity = capacity
        self.fifo = []

    def enqueue(self, wants, vals):
        """wants: (P, B) bool; vals: (P, B, width).  Returns grants."""
        wants = np.asarray(wants)
        space = self.capacity - len(self.fifo)
        grants = np.zeros_like(wants, bool)
        r = 0
        for p in range(wants.shape[0]):
            for b in range(wants.shape[1]):
                if wants[p, b]:
                    if r < space:
                        grants[p, b] = True
                        self.fifo.append(np.asarray(vals)[p, b])
                    r += 1
        return grants

    def dequeue(self, wants):
        """Returns (grants, values) with values zeros where not granted."""
        wants = np.asarray(wants)
        avail = len(self.fifo)
        grants = np.zeros_like(wants, bool)
        vals = {}
        r = 0
        for p in range(wants.shape[0]):
            for b in range(wants.shape[1]):
                if wants[p, b]:
                    if r < avail:
                        grants[p, b] = True
                        vals[(p, b)] = self.fifo.pop(0)
                    r += 1
        return grants, vals


def assert_queue_window_round(q, got_grants, got_vals, oracle_grants,
                              oracle_vals=None):
    np.testing.assert_array_equal(np.asarray(got_grants),
                                  oracle_grants)
    if oracle_vals is not None:
        got_vals = np.asarray(got_vals)
        for (p, b), v in oracle_vals.items():
            np.testing.assert_array_equal(got_vals[p, b], v)
        dead = ~oracle_grants
        assert np.all(got_vals[dead] == 0), \
            "non-granted dequeue lanes must return zeros"


def _tree_equal(a, b):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    assert len(la) == len(lb)
    return all(bool(jnp.all(x == y)) for x, y in zip(la, lb))


class TestSharedQueueWindows:
    B = 3
    WIDTH = 2

    def _mk(self, tag, slots_per_node=4, width=WIDTH):
        mgr = make_manager(P)
        q = SharedQueue(None, f"qw_{tag}", mgr, slots_per_node=slots_per_node,
                        width=width)
        return mgr, q, q.init_state()

    def _step(self, mgr, q):
        @jax.jit
        def step(st, ew, ev, dw):
            def prog(st, ew, ev, dw):
                st, g = q.enqueue_window(st, ev, ew)
                st, v, ok = q.dequeue_window(st, dw)
                return st, g, v, ok
            return mgr.runtime.run(prog, st, ew, ev, dw)
        return step

    def test_mixed_push_pop_windows_match_fifo_oracle(self):
        mgr, q, st = self._mk("oracle")
        step = self._step(mgr, q)
        oracle = QueueWindowOracle(q.capacity)
        rng = np.random.default_rng(7)
        for rnd in range(6):
            ew = rng.random((P, self.B)) < 0.7
            dw = rng.random((P, self.B)) < 0.7
            ev = rng.integers(1, 1000, (P, self.B, self.WIDTH)).astype(
                np.int32)
            st, g, v, ok = step(st, jnp.asarray(ew), jnp.asarray(ev),
                                jnp.asarray(dw))
            eg = oracle.enqueue(ew, ev)
            dg, dv = oracle.dequeue(dw)
            assert_queue_window_round(q, g, None, eg)
            assert_queue_window_round(q, ok, v, dg, dv)

    def test_full_queue_rejects_rank_suffix(self):
        # capacity 4 (1 slot/node): 12 wanting lanes → exactly ranks 0–3
        # granted = all of p0's window plus p1's first lane
        mgr, q, st = self._mk("full", slots_per_node=1)
        step = self._step(mgr, q)
        ev = np.arange(P * self.B * self.WIDTH, dtype=np.int32).reshape(
            P, self.B, self.WIDTH)
        st, g, _v, ok = step(st, jnp.ones((P, self.B), bool),
                             jnp.asarray(ev), jnp.zeros((P, self.B), bool))
        expect = np.zeros((P, self.B), bool)
        expect[0, :] = True
        expect[1, 0] = True
        np.testing.assert_array_equal(np.asarray(g), expect)
        assert not np.any(np.asarray(ok))

    def test_empty_queue_rejects_pop_rank_suffix(self):
        mgr, q, st = self._mk("empty")
        step = self._step(mgr, q)
        # two items in the queue, five wanting pop lanes → ranks 0–1 pop
        ew = np.zeros((P, self.B), bool)
        ew[0, 0] = ew[2, 1] = True
        ev = np.full((P, self.B, self.WIDTH), 9, np.int32)
        dw = np.zeros((P, self.B), bool)
        dw[0, 2] = dw[1, 0] = dw[1, 2] = dw[3, 0] = dw[3, 1] = True
        st, g, v, ok = step(st, jnp.asarray(ew), jnp.asarray(ev),
                            jnp.asarray(dw))
        expect = np.zeros((P, self.B), bool)
        expect[0, 2] = expect[1, 0] = True        # lex ranks 0 and 1
        np.testing.assert_array_equal(np.asarray(ok), expect)
        assert np.all(np.asarray(v)[~expect] == 0)

    def test_pred_masked_lanes_never_rank(self):
        # a masked lane between two enabled ones must not consume a rank
        mgr, q, st = self._mk("mask", slots_per_node=1)  # capacity 4
        step = self._step(mgr, q)
        ew = np.ones((P, self.B), bool)
        ew[0, 1] = ew[1, :] = False               # p0 lane1 + all of p1 out
        ev = np.arange(P * self.B * self.WIDTH, dtype=np.int32).reshape(
            P, self.B, self.WIDTH)
        st, g, _v, _ok = step(st, jnp.asarray(ew), jnp.asarray(ev),
                              jnp.zeros((P, self.B), bool))
        # enabled lanes in lex order: (0,0) (0,2) (2,0) (2,1) (2,2) (3,0)…
        expect = np.zeros((P, self.B), bool)
        expect[0, 0] = expect[0, 2] = expect[2, 0] = expect[2, 1] = True
        np.testing.assert_array_equal(np.asarray(g), expect)

    def test_b1_window_pinned_to_scalar_reference(self):
        """The B=1 wrappers (enqueue/dequeue) replay a mixed scalar
        sequence bit-for-bit against the retained reference paths: state
        leaves identical after every round, grant/ok lanes identical,
        values identical on EVERY lane (the PR-5 pred audit zero-masks
        failed scalar pops too, so the last documented divergence is
        closed)."""
        mgr, q, st_w = self._mk("pin", slots_per_node=2)
        st_r = st_w

        @jax.jit
        def round_w(st, ew, ev, dw):
            def prog(st, ew, ev, dw):
                st, g = q.enqueue(st, ev, want=ew)
                st, v, ok = q.dequeue(st, want=dw)
                return st, g, v, ok
            return mgr.runtime.run(prog, st, ew, ev, dw)

        @jax.jit
        def round_r(st, ew, ev, dw):
            def prog(st, ew, ev, dw):
                st, g = q._enqueue_reference(st, ev, want=ew)
                st, v, ok = q._dequeue_reference(st, want=dw)
                return st, g, v, ok
            return mgr.runtime.run(prog, st, ew, ev, dw)

        rng = np.random.default_rng(3)
        for rnd in range(8):
            ew = jnp.asarray(rng.random(P) < 0.6)
            dw = jnp.asarray(rng.random(P) < 0.6)
            ev = jnp.asarray(rng.integers(1, 99, (P, self.WIDTH)), jnp.int32)
            st_w, gw, vw, okw = round_w(st_w, ew, ev, dw)
            st_r, gr, vr, okr = round_r(st_r, ew, ev, dw)
            assert _tree_equal(st_w, st_r), f"state diverged at round {rnd}"
            np.testing.assert_array_equal(np.asarray(gw), np.asarray(gr))
            np.testing.assert_array_equal(np.asarray(okw), np.asarray(okr))
            np.testing.assert_array_equal(np.asarray(vw), np.asarray(vr))

    def test_single_participant_window_equals_scalar_rounds(self):
        """One active participant: the window's (participant, lane) order
        degenerates to the scalar sequence, so a (B,) window is bitwise
        one round-set of B reference enqueues."""
        mgr, q, st0 = self._mk("seq")
        ev = np.arange(1, 1 + self.B * self.WIDTH, dtype=np.int32).reshape(
            self.B, self.WIDTH)

        @jax.jit
        def win(st, ev):
            def prog(st, ev):
                me = mgr.runtime.my_id()
                st, g = q.enqueue_window(
                    st, ev, jnp.broadcast_to(me == 0, (self.B,)))
                return st, g
            return mgr.runtime.run(prog, st, ev)

        @jax.jit
        def seq(st, ev):
            def prog(st, ev):
                me = mgr.runtime.my_id()
                gs = []
                for b in range(self.B):
                    st, g = q._enqueue_reference(st, ev[b], want=me == 0)
                    gs.append(g)
                return st, jnp.stack(gs)
            return mgr.runtime.run(prog, st, ev)

        evb = jnp.broadcast_to(jnp.asarray(ev), (P, self.B, self.WIDTH))
        st_w, gw = win(st0, evb)
        st_s, gs = seq(st0, evb)
        assert _tree_equal(st_w, st_s)
        np.testing.assert_array_equal(np.asarray(gw), np.asarray(gs))

    def test_masked_window_lanes_cost_zero_wire_bytes(self):
        """Regression for the pred-handling audit (DESIGN.md §9.1): dead
        lanes never ride the wire on EITHER dequeue path — an all-masked
        dequeue window records ZERO modeled read bytes, and so does the
        scalar reference since the PR-5 fix gave its slot read a pred."""
        mgr, q, st = self._mk("wire")
        mgr.traffic.enable().reset()
        fresh = jax.jit(lambda s: mgr.runtime.run(
            lambda ss: q.dequeue_window(ss, jnp.zeros((self.B,), bool)), s))
        jax.block_until_ready(jax.tree.leaves(fresh(st)))
        win_bytes = mgr.traffic.total_bytes()
        mgr.traffic.reset()
        fresh_ref = jax.jit(lambda s: mgr.runtime.run(
            lambda ss: q._dequeue_reference(ss, want=False), s))
        jax.block_until_ready(jax.tree.leaves(fresh_ref(st)))
        ref_bytes = mgr.traffic.total_bytes()
        mgr.traffic.disable().reset()
        assert win_bytes == 0.0, \
            "masked dequeue lanes must not ride the wire"
        assert ref_bytes == 0.0, \
            "the scalar dequeue's slot read must honor its pred"


# --------------------------------------------- windowed ringbuffer (§9.2)
class TestRingbufferWindows:
    B = 4
    WIDTH = 3

    def _mk(self, tag, capacity=8):
        mgr = make_manager(P)
        rb = Ringbuffer(None, f"rbw_{tag}", mgr, owner=0, capacity=capacity,
                        width=self.WIDTH)
        return mgr, rb, rb.init_state()

    def _step(self, mgr, rb):
        @jax.jit
        def step(st, msgs, lens, preds):
            def prog(st, msgs, lens, preds):
                st, sent, _ = rb.publish_window(st, msgs, lens, preds)
                st, m, l, got, _f = rb.recv_window(st, self.B)
                return st, sent, m, l, got
            return mgr.runtime.run(prog, st, msgs, lens, preds)
        return step

    def _msgs(self, base):
        m = (np.arange(self.B * self.WIDTH, dtype=np.int32)
             .reshape(self.B, self.WIDTH) + 100 * base)
        return np.broadcast_to(m, (P, self.B, self.WIDTH)).copy()

    def test_window_broadcast_in_order_with_wrap(self):
        mgr, rb, st = self._mk("wrap", capacity=5)  # wraps on round 2
        step = self._step(mgr, rb)
        for rnd in range(3):
            msgs = self._msgs(rnd)
            lens = np.broadcast_to(
                np.arange(1, self.B + 1, dtype=np.int32),
                (P, self.B)).copy()
            st, sent, m, l, got = step(
                st, jnp.asarray(msgs), jnp.asarray(lens),
                jnp.ones((P, self.B), bool))
            assert np.all(np.asarray(sent)[0]), "owner publishes all lanes"
            assert not np.any(np.asarray(sent)[1:]), "non-owners never send"
            assert np.all(np.asarray(got)), "every consumer drains in order"
            np.testing.assert_array_equal(np.asarray(m), msgs)
            np.testing.assert_array_equal(np.asarray(l), lens)

    def test_full_ring_grants_prefix_and_resumes_after_acks(self):
        mgr, rb, st = self._mk("full", capacity=6)

        @jax.jit
        def pub_only(st, msgs, lens):
            def prog(st, msgs, lens):
                st, sent, _ = rb.publish_window(st, msgs, lens)
                return st, sent
            return mgr.runtime.run(prog, st, msgs, lens)

        @jax.jit
        def drain(st):
            def prog(st):
                st, m, l, got, _f = rb.recv_window(st, self.B)
                return st, got
            return mgr.runtime.run(prog, st)

        msgs = self._msgs(0)
        lens = np.full((P, self.B), self.WIDTH, np.int32)
        st, sent1 = pub_only(st, jnp.asarray(msgs), jnp.asarray(lens))
        assert np.all(np.asarray(sent1)[0])               # 4 of 6 slots used
        st, sent2 = pub_only(st, jnp.asarray(self._msgs(1)),
                             jnp.asarray(lens))
        # only 2 slots left: grant is the first-2 lane prefix, never a
        # scattered subset
        np.testing.assert_array_equal(np.asarray(sent2)[0],
                                      [True, True, False, False])
        st, got = drain(st)
        assert np.all(np.asarray(got))                    # drains 4 + backlog
        st, got = drain(st)
        assert np.asarray(got).sum(axis=1).tolist() == [2] * P
        st, sent3 = pub_only(st, jnp.asarray(self._msgs(2)),
                             jnp.asarray(lens))
        assert np.all(np.asarray(sent3)[0]), "acks free the ring again"

    def test_b1_window_pinned_to_scalar_send_recv(self):
        mgr, rb, st_w = self._mk("pin")
        st_r = st_w

        @jax.jit
        def round_w(st, msg, ln, pred):
            def prog(st, msg, ln, pred):
                st, sent, _ = rb.publish_window(
                    st, msg[None, :], jnp.reshape(ln, (1,)),
                    jnp.reshape(pred, (1,)))
                st, m, l, got, _f = rb.recv_window(st, 1)
                return st, sent[0], m[0], l[0], got[0]
            return mgr.runtime.run(prog, st, msg, ln, pred)

        @jax.jit
        def round_r(st, msg, ln, pred):
            def prog(st, msg, ln, pred):
                st, sent, _ = rb.send(st, msg, ln, pred=pred)
                st, m, l, got = rb.recv_one(st)
                return st, sent, m, l, got
            return mgr.runtime.run(prog, st, msg, ln, pred)

        rng = np.random.default_rng(5)
        for rnd in range(6):
            msg = jnp.broadcast_to(
                jnp.asarray(rng.integers(0, 99, self.WIDTH), jnp.int32),
                (P, self.WIDTH))
            ln = jnp.full((P,), int(rng.integers(1, self.WIDTH + 1)),
                          jnp.int32)
            pred = jnp.full((P,), bool(rng.random() < 0.8))
            st_w, *out_w = round_w(st_w, msg, ln, pred)
            st_r, *out_r = round_r(st_r, msg, ln, pred)
            assert _tree_equal(st_w, st_r), f"state diverged at round {rnd}"
            for a, b in zip(out_w, out_r):
                np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_corrupt_length_never_validates(self):
        """Regression for the slot-checksum coverage fix: the seed
        checksummed the payload alone, so a corrupted length word
        delivered a "valid" message of the wrong size.  The checksum now
        covers (payload, seq, len) — any single-field corruption must
        fail validation and stall the cursor."""
        mgr, rb, st = self._mk("tear")

        @jax.jit
        def pub(st, msgs, lens):
            return mgr.runtime.run(
                lambda s, m, l: rb.publish_window(s, m, l)[0],
                st, msgs, lens)

        @jax.jit
        def drain(st):
            def prog(st):
                return rb.recv_window(st, self.B)
            return mgr.runtime.run(prog, st)

        msgs = self._msgs(0)
        lens = np.full((P, self.B), 2, np.int32)
        st = pub(st, jnp.asarray(msgs), jnp.asarray(lens))

        for field, delta in (("length", 1), ("payload", 7), ("seq", 1)):
            buf = np.asarray(getattr(st, field)).copy()
            corrupt = st._replace(**{field: jnp.asarray(
                buf + np.asarray(delta, buf.dtype))})
            _st2, _m, _l, got, _f = drain(corrupt)
            assert not np.any(np.asarray(got)), \
                f"corrupted {field} must never deliver"
        # uncorrupted state still drains everything
        _st3, m, _l, got, _f = drain(st)
        assert np.all(np.asarray(got))
        np.testing.assert_array_equal(np.asarray(m), msgs)

    def test_checksum_failure_lands_in_traffic_ledger(self):
        """§12 satellite: validation failures are observable, not just
        silently stalled — a corrupted slot increments the per-channel
        ``corrupt`` ledger counter, while ordinary staleness (slots past
        ``head``, never-written seq words) counts nothing.  Ledger
        gating is trace-time, so traffic is enabled *before* the jitted
        drain is built."""
        mgr, rb, st = self._mk("ledger")
        mgr.traffic.enable().reset()

        @jax.jit
        def pub(st, msgs, lens):
            return mgr.runtime.run(
                lambda s, m, l: rb.publish_window(s, m, l)[0],
                st, msgs, lens)

        @jax.jit
        def drain(st):
            return mgr.runtime.run(lambda s: rb.recv_window(s, self.B), st)

        try:
            msgs = self._msgs(0)
            lens = np.full((P, self.B), 2, np.int32)
            st = pub(st, jnp.asarray(msgs), jnp.asarray(lens))
            # clean drain: everything validates, nothing is counted
            _st2, _m, _l, got, _f = drain(st)
            assert np.all(np.asarray(got))
            assert mgr.traffic.corrupt_summary().get(
                rb.full_name, 0.0) == 0.0
            # flip one payload word in consumer 1's cached copy only
            buf = np.asarray(st.payload).copy()
            buf[1, 0, 0] ^= 0x5A
            _st3, _m, _l, got, _f = drain(
                st._replace(payload=jnp.asarray(buf)))
            got = np.asarray(got)
            assert not got[1].any(), "corrupt head slot stalls consumer 1"
            assert got[0].all() and got[2:].all(), \
                "other consumers' cached copies are untouched"
            assert mgr.traffic.corrupt_summary()[rb.full_name] == 1.0
        finally:
            mgr.traffic.disable()

    def test_recv_one_pred_masks_consumption(self):
        """Pred-handling regression (DESIGN.md §9.1): a masked consumer
        neither advances its cursor nor leaks the slot's bits."""
        mgr, rb, st = self._mk("pred")

        @jax.jit
        def prog(st):
            def body(st):
                me = mgr.runtime.my_id()
                msg = jnp.arange(self.WIDTH, dtype=jnp.int32) + 1
                st, _s, _ = rb.send(st, msg, self.WIDTH, pred=me == 0)
                st, m, l, got = rb.recv_one(st, pred=me % 2 == 0)
                return st, m, l, got
            return mgr.runtime.run(body, st)

        st, m, l, got = prog(st)
        got = np.asarray(got)
        np.testing.assert_array_equal(got, [True, False, True, False])
        m, l = np.asarray(m), np.asarray(l)
        assert np.all(m[1] == 0) and np.all(m[3] == 0) and l[1] == l[3] == 0
        np.testing.assert_array_equal(m[0], np.arange(self.WIDTH) + 1)
        # masked consumers' cursors did not advance
        acks = np.asarray(st.acks.cached)
        np.testing.assert_array_equal(acks[0], [1, 0, 1, 0])


# --------------------------------------------------------------- manager/fences
class TestManagerAndFences:
    def test_channel_name_collision_rejected(self):
        mgr = make_manager(P)
        OwnedVar(None, "x", mgr, owner=0)
        with pytest.raises(ValueError, match="collision"):
            OwnedVar(None, "x", mgr, owner=1)

    def test_memory_ledger_accounts_regions(self):
        mgr = make_manager(P)
        SharedRegion(None, "r", mgr, slots=10, item_shape=(4,),
                     dtype=jnp.float32)
        assert mgr.memory_ledger_bytes() == 10 * 4 * 4

    def test_fence_scopes_tracked(self):
        mgr = make_manager(P)
        ov = OwnedVar(None, "v", mgr, owner=0, shape=(2,), dtype=jnp.float32)
        st = ov.init_state()

        def prog(st):
            with mgr.tracking():
                st2, _ = ov.push(st)
                out = mgr.fence(st2.cached, scope=FenceScope.GLOBAL)
            return out

        out = run(mgr, prog, st)
        assert out.shape == (P, 2)
        assert mgr.fence_counts[FenceScope.GLOBAL] >= 1

    def test_pair_fence_keeps_other_ops_outstanding(self):
        mgr = make_manager(P)
        ov0 = OwnedVar(None, "a", mgr, owner=0, shape=(), dtype=jnp.float32)
        ov1 = OwnedVar(None, "b", mgr, owner=1, shape=(), dtype=jnp.float32)
        s0, s1 = ov0.init_state(), ov1.init_state()

        def prog(s0, s1):
            with mgr.tracking():
                s0b, _ = ov0.pull(s0)   # targets peer 0
                s1b, _ = ov1.pull(s1)   # targets peer 1
                _ = mgr.fence(s0b.cached, scope=FenceScope.PAIR, peer=0)
                still_out = mgr.outstanding()
                assert len(still_out.descs) == 1  # peer-1 op still pending
                assert still_out.descs[0].peers == (1,)
            return s0b.cached

        run(mgr, prog, s0, s1)


# ------------------------------------------------- coalesced read verbs (§8.1)
class TestCoalescedReads:
    """remote_read_coalesced / remote_read_batch(coalesce=True): modeled
    wire bytes scale with *unique* enabled remote (target, index) pairs —
    duplicates fan out locally — and results stay bitwise-identical to the
    uncoalesced verb on every lane pattern."""

    ITEM = 8            # item_shape=(2,) int32 → 8 payload bytes per row
    R = 6

    def _setup(self, tag):
        mgr = make_manager(P)
        reg = SharedRegion(None, f"coal_{tag}", mgr, slots=4,
                           item_shape=(2,), dtype=jnp.int32)
        st = reg.init_state()
        # distinct, recognizable rows: row[i] at participant p = (100p+i)·(1, 10)
        buf = (np.arange(P)[:, None, None] * 100
               + np.arange(4)[None, :, None]) * np.array([1, 10])[None, None, :]
        st = st._replace(buf=jnp.asarray(buf, jnp.int32))
        return mgr, reg, st

    def _read(self, mgr, reg, st, tgts, idxs, preds=None, coalesce=True):
        """tgts/idxs/preds: (P, R) per-participant lanes.  Returns
        (values (P, R, 2), modeled wire bytes)."""
        tp = jnp.asarray(tgts, jnp.int32)
        ip = jnp.asarray(idxs, jnp.int32)
        pp = None if preds is None else jnp.asarray(preds)
        mgr.traffic.enable().reset()

        def prog(st, t, i, *p):
            got, _ = reg.read_batch(st, t, i, preds=p[0] if p else None,
                                    coalesce=coalesce)
            return got

        args = (st, tp, ip) + ((pp,) if pp is not None else ())
        got = run(mgr, prog, *args)
        jax.block_until_ready(got)
        total = mgr.traffic.total_bytes()
        mgr.traffic.disable().reset()
        return np.asarray(got), total

    def _expect(self, tgts, idxs, preds=None):
        """Reference values + unique/total remote lane counts (numpy)."""
        tgts, idxs = np.asarray(tgts), np.asarray(idxs)
        preds = np.ones_like(tgts, bool) if preds is None else np.asarray(preds)
        vals = np.zeros(tgts.shape + (2,), np.int64)
        uniq = total = 0
        for p in range(P):
            seen = set()
            for r in range(tgts.shape[1]):
                if not preds[p, r]:
                    continue
                t, i = int(tgts[p, r]), int(idxs[p, r])
                vals[p, r] = (100 * t + i) * np.array([1, 10])
                if t != p:
                    total += 1
                    if (t, i) not in seen:
                        seen.add((t, i))
                        uniq += 1
        return vals, uniq, total

    def test_duplicate_heavy_lanes_pay_unique_rows_only(self):
        mgr, reg, st = self._setup("dup")
        tgts = np.stack([np.full(self.R, (p + 1) % P) for p in range(P)])
        idxs = np.zeros((P, self.R), np.int64)      # all lanes, one hot row
        vals, uniq, total = self._expect(tgts, idxs)
        got_c, bytes_c = self._read(mgr, reg, st, tgts, idxs, coalesce=True)
        got_d, bytes_d = self._read(mgr, reg, st, tgts, idxs, coalesce=False)
        np.testing.assert_array_equal(got_c, vals)
        np.testing.assert_array_equal(got_c, got_d)    # bitwise-identical
        assert uniq == P and total == P * self.R
        assert bytes_c == 2 * self.ITEM * uniq         # one row per part.
        assert bytes_d == 2 * self.ITEM * total        # R rows per part.

    def test_all_self_lanes_cost_zero(self):
        mgr, reg, st = self._setup("self")
        tgts = np.repeat(np.arange(P)[:, None], self.R, axis=1)
        idxs = np.tile(np.arange(self.R) % 4, (P, 1))
        vals, uniq, total = self._expect(tgts, idxs)
        assert uniq == total == 0
        got_c, bytes_c = self._read(mgr, reg, st, tgts, idxs, coalesce=True)
        np.testing.assert_array_equal(got_c, vals)
        assert bytes_c == 0.0

    def test_all_unique_lanes_match_uncoalesced_cost(self):
        mgr, reg, st = self._setup("uniq")
        # R=4 distinct (target, index) pairs per participant, all remote
        tgts = np.stack([[(p + 1) % P, (p + 1) % P,
                          (p + 2) % P, (p + 3) % P] for p in range(P)])
        idxs = np.tile([0, 1, 0, 2], (P, 1))
        vals, uniq, total = self._expect(tgts, idxs)
        assert uniq == total == 4 * P
        got_c, bytes_c = self._read(mgr, reg, st, tgts, idxs, coalesce=True)
        got_d, bytes_d = self._read(mgr, reg, st, tgts, idxs, coalesce=False)
        np.testing.assert_array_equal(got_c, vals)
        np.testing.assert_array_equal(got_c, got_d)
        assert bytes_c == bytes_d == 2 * self.ITEM * uniq

    def test_disabled_duplicates_neither_lead_nor_count(self):
        mgr, reg, st = self._setup("pred")
        tgts = np.stack([np.full(self.R, (p + 1) % P) for p in range(P)])
        idxs = np.tile(np.arange(self.R) % 2, (P, 1))  # two hot rows
        preds = np.tile([False, True, True, False, True, False], (P, 1))
        vals, uniq, total = self._expect(tgts, idxs, preds)
        got_c, bytes_c = self._read(mgr, reg, st, tgts, idxs, preds, True)
        np.testing.assert_array_equal(got_c, vals)     # disabled → zeros
        assert bytes_c == 2 * self.ITEM * uniq
        assert uniq == 2 * P                           # rows 0 and 1 each

    @pytest.mark.parametrize("seed", range(3))
    def test_random_patterns_bitwise_equal_and_cheaper(self, seed):
        rng = np.random.default_rng(seed)
        mgr, reg, st = self._setup(f"rand{seed}")
        tgts = rng.integers(0, P, (P, self.R))
        idxs = rng.integers(0, 4, (P, self.R))
        preds = rng.random((P, self.R)) < 0.8
        vals, uniq, total = self._expect(tgts, idxs, preds)
        got_c, bytes_c = self._read(mgr, reg, st, tgts, idxs, preds, True)
        got_d, bytes_d = self._read(mgr, reg, st, tgts, idxs, preds, False)
        np.testing.assert_array_equal(got_c, got_d)
        np.testing.assert_array_equal(got_c, vals)
        assert bytes_c == 2 * self.ITEM * uniq
        assert bytes_d == 2 * self.ITEM * total
        assert bytes_c <= bytes_d
