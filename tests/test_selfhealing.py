"""Self-healing replication (DESIGN.md §13): heartbeat failure
detection, crash-safe cascading promotion, and follower rejoin.

Checked here:

* **detector semantics** (§13.1) — threshold edge, the false-positive
  window (a slow node that resumes bumping under the threshold is never
  suspected), sticky deadness with :meth:`readmit` as the only way
  back, SPMD-uniform verdicts, detection-latency bookkeeping;
* **heartbeat-driven detection at the log level** — a ``FaultPlan``
  only *silences* the victim; ``heartbeat_and_detect`` reaches the
  verdict from the stalled ptable heartbeat column and evicts the dead
  cursor from ring flow control;
* **cascading promotion** (§13.2) — the winner of promotion #1 dies at
  every step boundary (after gather, after fence, mid-re-publish via
  the ``limit`` hook); a fresh :meth:`promote` restarts from the
  durable fence heads and cursors with zero acked-window loss and
  bitwise convergence (double AND triple cascades, swept under
  ``torture``);
* **rejoin** (§13.3) — ``needs_snapshot`` decides snapshot-vs-replay;
  the chunked transfer converges bitwise; a racing mutation window and
  a leader death mid-transfer each restart the staging (resumability)
  and still converge; a fuzz sweep interleaves interruptions at varying
  rounds under ``torture``;
* **bounded backoff** (§13 satellite) — drop-then-recover at each
  ``max_attempts`` stage with the success-attempt histogram
  (``retries_by_attempt``) asserted exactly.

Mutations route through lanes that stay alive for the scenario (the
``test_failover`` masking discipline): a dead participant's slice of a
log entry would have no live submitter at replay.  Windows driven while
the current owner is already dead are all-NOP — the engine buffers such
windows rather than applying them leader-side unreplicated.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (INSERT, NOP, UPDATE, FailureDetector, KVStore,
                        ReplicatedLog, make_manager)
from repro.core.replog import diverging_leaves
from repro.distributed.fault import FaultPlan

P = 4
B = 2
CAP = 4
THRESH = 2

mgr = make_manager(P)
_kw = dict(slots_per_node=6, value_width=2, num_locks=8, index_capacity=64)
leader = KVStore(None, "sh_leader", mgr, **_kw)
follower = KVStore(None, "sh_follower", mgr, **_kw)
log = ReplicatedLog(None, "sh_log", mgr, store=leader, window=B,
                    capacity=CAP, rejoin_chunk=32)
det = FailureDetector(None, "sh_det", mgr, threshold=THRESH)

NL = (NOP, 1, (0, 0))
ALL = np.ones(P, bool)


def window(*lanes):
    op = jnp.asarray([[o[0] for o in ln] for ln in lanes], jnp.int32)
    key = jnp.asarray([[o[1] for o in ln] for ln in lanes], jnp.uint32)
    val = jnp.asarray([[o[2] for o in ln] for ln in lanes], jnp.int32)
    return op, key, val


WNOP = window(*[[NL] * B for _ in range(P)])


def wmut(*triples, dead=(0,)):
    """A window with ``dead`` lanes all-NOP and ``triples`` spread over
    the remaining lanes (live-submitter replay discipline)."""
    live = [p for p in range(P) if p not in dead]
    lanes = [[NL] * B for _ in range(P)]
    for i, t in enumerate(triples):
        lanes[live[i % len(live)]][i // len(live)] = t
    return window(*lanes)


def mkw(i, dead=(0,)):
    """Deterministic mutation window ``i`` routed around ``dead`` lanes."""
    k = 1 + (i % 5)
    return wmut((INSERT if i < 5 else UPDATE, k, (10 * k + i, i)),
                (UPDATE if i >= 5 else INSERT, k + 5, (20 * k, i)),
                dead=dead)


def alive_stacked(mask):
    return jnp.broadcast_to(jnp.asarray(mask, bool), (P, P))


def states():
    return (leader.init_state(), follower.init_state(), log.init_state(),
            det.init_state())


@jax.jit
def hb_step(lst, fst, gst, dst, op, key, val, alive):
    """One serving window under the §13 protocol: leader apply +
    heartbeat/observe + append through the current owner + live-lane
    sync.  ``alive`` is the PHYSICAL liveness injection; the verdict
    comes back from the detector."""
    def prog(lst, fst, gst, dst, op, key, val, alive):
        me = mgr.runtime.my_id()
        lst, _res = leader.op_window(lst, op, key, val)
        gst, dst, verdict = log.heartbeat_and_detect(gst, dst, det,
                                                     pred=alive[me])
        gst, fst, ok, applied = log.append_with_retry(
            gst, op, key, val, follower, fst, max_attempts=2,
            pred=alive[gst.ring.owner], sync_pred=alive[me])
        return lst, fst, gst, dst, verdict, ok, applied
    return mgr.runtime.run(prog, lst, fst, gst, dst, op, key, val, alive)


@jax.jit
def append_ns(lst, gst, op, key, val, alive):
    """Append WITHOUT the built-in drains — builds the unacked suffix
    the cascade tests re-publish."""
    def prog(lst, gst, op, key, val, alive):
        lst, _res = leader.op_window(lst, op, key, val)
        gst, ok = log.append(gst, op, key, val, pred=alive[gst.ring.owner])
        return lst, gst, ok
    return mgr.runtime.run(prog, lst, gst, op, key, val, alive)


@jax.jit
def sync_mask(gst, fst, mask):
    def prog(gst, fst, mask):
        gst, fst, applied = log.sync(gst, follower, fst, max_entries=1,
                                     pred=mask)
        return gst, fst, applied, log.lag(gst)
    return mgr.runtime.run(prog, gst, fst, mask)


@jax.jit
def observe_j(dst, hb):
    return mgr.runtime.run(lambda d, h: det.observe(d, h), dst, hb)


@jax.jit
def promote_j(gst, alive):
    return mgr.runtime.run(log.promote, gst, alive)


@jax.jit
def gather_j(gst, alive):
    return mgr.runtime.run(log.promote_gather, gst, alive)


@jax.jit
def fence_j(gst, alive):
    return mgr.runtime.run(log.promote_fence, gst, alive)


_REPUB = {}


def repub_j(limit):
    if limit not in _REPUB:
        _REPUB[limit] = jax.jit(lambda gst, alive: mgr.runtime.run(
            lambda g, a: log.promote_republish(g, a, limit=limit),
            gst, alive))
    return _REPUB[limit]


@jax.jit
def rejoin_j(gst, rst, lst, fst, node):
    def prog(gst, rst, lst, fst, node):
        return log.rejoin_step(gst, rst, lst, follower, fst, node)
    return mgr.runtime.run(prog, gst, rst, lst, fst, node)


def lane_arg(p):
    """Per-lane broadcast of a scalar node id (runtime.run vmaps args)."""
    return jnp.full((P,), p, jnp.int32)


def lag_of(gst):
    return int(np.asarray(mgr.runtime.run(log.lag, gst))[0])


def assert_converged(lst, fst, lanes=None, what="leader/follower"):
    diverged = diverging_leaves(jax.tree.map(np.asarray, lst),
                                jax.tree.map(np.asarray, fst), lanes=lanes)
    assert not diverged, f"{what} diverged on leaves {diverged}"


def drive(n, lst, fst, gst, dst, alive, dead=(0,), start=0):
    """``n`` mutation windows under physical mask ``alive``, ops routed
    around ``dead``; returns final states + last verdict."""
    verdict = None
    for i in range(start, start + n):
        lst, fst, gst, dst, verdict, _ok, _n = hb_step(
            lst, fst, gst, dst, *mkw(i, dead=dead), alive_stacked(alive))
    return lst, fst, gst, dst, verdict


class TestDetectorSemantics:
    def hb_table(self, col):
        """Stacked (P, P) gathered heartbeat column (all lanes agree)."""
        return jnp.broadcast_to(jnp.asarray(col, jnp.uint32), (P, P))

    def test_threshold_edge_and_detection_latency(self):
        dst = det.init_state()
        hb = np.zeros(P, np.uint32)
        hb += 1                               # window 1: everyone bumps
        dst, alive = observe_j(dst, self.hb_table(hb))
        assert np.asarray(alive)[0].all()
        hb[[0, 1, 3]] += 1                    # node 2 stalls
        dst, alive = observe_j(dst, self.hb_table(hb))
        assert np.asarray(alive)[0].all(), "one miss is below threshold"
        hb[[0, 1, 3]] += 1                    # second consecutive miss
        dst, alive = observe_j(dst, self.hb_table(hb))
        a = np.asarray(alive)[0]
        assert not a[2] and a[[0, 1, 3]].all()
        assert np.all(np.asarray(alive) == a), \
            "the verdict must be SPMD-uniform"
        lat = mgr.runtime.run(lambda d: det.detection_latency(d, 2), dst)
        assert int(np.asarray(lat)[0]) == 3, \
            "declared dead on observation window 3 (last bump at 1 + 2)"

    def test_false_positive_window_resume_under_threshold(self):
        """A slow-but-alive node that resumes bumping after threshold-1
        missed windows is never suspected."""
        dst = det.init_state()
        hb = np.zeros(P, np.uint32)
        for _ in range(2):
            hb += 1
            dst, alive = observe_j(dst, self.hb_table(hb))
        hb[[0, 2, 3]] += 1      # node 1 stalls threshold-1 windows...
        dst, alive = observe_j(dst, self.hb_table(hb))
        assert np.asarray(alive)[0].all()
        hb += 1                 # ...then resumes: miss count resets
        dst, alive = observe_j(dst, self.hb_table(hb))
        assert np.asarray(alive)[0].all()
        assert int(np.asarray(dst.missed)[0, 1]) == 0
        for _ in range(3):
            hb += 1
            dst, alive = observe_j(dst, self.hb_table(hb))
        assert np.asarray(alive)[0].all()

    def test_dead_is_sticky_until_readmit(self):
        dst = det.init_state()
        hb = np.zeros(P, np.uint32)
        hb += 1
        dst, _ = observe_j(dst, self.hb_table(hb))
        for _ in range(THRESH):
            hb[[1, 2, 3]] += 1
            dst, alive = observe_j(dst, self.hb_table(hb))
        assert not np.asarray(alive)[0][0]
        for _ in range(3):      # resumed bumps do NOT readmit
            hb += 1
            dst, alive = observe_j(dst, self.hb_table(hb))
        assert not np.asarray(alive)[0][0], "a declared-dead node must " \
            "rejoin explicitly, not drift back in"
        dst = mgr.runtime.run(lambda d: det.readmit(d, 0), dst)
        assert np.asarray(dst.alive)[0].all()
        assert int(np.asarray(dst.missed)[0, 0]) == 0
        assert int(np.asarray(dst.detected_at)[0, 0]) == 0xFFFFFFFF

    def test_threshold_validation(self):
        with pytest.raises(ValueError, match="threshold"):
            FailureDetector(None, "sh_det_bad", mgr, threshold=0)


class TestHeartbeatDetection:
    def test_stalled_heartbeats_reach_verdict_and_evict(self):
        """FaultPlan only *silences* node 0; the detector discovers the
        death from the stalled ptable heartbeat column within THRESH
        windows and evicts the dead cursor from ring flow control."""
        lst, fst, gst, dst = states()
        plan = FaultPlan(kills={0: 2})
        alive = ALL.copy()
        verdicts = []
        for w in range(2 + THRESH):
            for p in plan.newly_dead(w):
                alive[p] = False
            # once the owner is dead, windows are all-NOP until the
            # promotion (the engine buffers them; appends through a dead
            # owner are pred-masked and would strand leader-side state)
            wnd = mkw(w) if alive[0] else WNOP
            lst, fst, gst, dst, verdict, _ok, _n = hb_step(
                lst, fst, gst, dst, *wnd, alive_stacked(alive))
            verdicts.append(np.asarray(verdict)[0].copy())
        assert verdicts[1].all(), "pre-kill windows must stay clean"
        assert verdicts[1 + THRESH - 1].all(), \
            "the verdict lands exactly at the threshold, not before"
        assert not verdicts[1 + THRESH][0], \
            "THRESH stalled windows must produce the death verdict"
        assert not bool(np.asarray(gst.ring.alive)[0, 0]), \
            "the verdict must evict the dead cursor from flow control"
        # verdict → promotion → serving continues, converged on live lanes
        v = verdicts[-1]
        gst, winner = promote_j(gst, alive_stacked(v))
        assert int(np.asarray(winner)[0]) != 0
        lst, fst, gst, dst, _verdict = drive(3, lst, fst, gst, dst, v,
                                             start=10)
        while lag_of(gst):
            gst, fst, _n, _l = sync_mask(gst, fst, jnp.asarray(v))
        assert_converged(lst, fst, lanes=v)
        assert int(np.asarray(gst.dropped)[0]) == 0


class TestCascadingPromotion:
    def _seed(self):
        lst, fst, gst, dst = states()
        lst, fst, gst, dst, _v = drive(3, lst, fst, gst, dst, ALL,
                                       dead=())
        return lst, fst, gst, dst

    def _suffix(self, lst, gst, dead):
        """Two acked-but-undrained windows whose mutations live only on
        lanes surviving the whole cascade."""
        for i in (3, 4):
            lst, gst, ok = append_ns(lst, gst, *mkw(i, dead=dead),
                                     alive_stacked(ALL))
            assert bool(np.asarray(ok)[0])
        return lst, gst

    def _finish(self, lst, fst, gst, dst, alive, start):
        """Post-cascade serving + drain, then convergence on live lanes
        and the zero-acked-loss check."""
        dead = tuple(int(p) for p in np.where(~alive)[0])
        lst, fst, gst, dst, _v = drive(3, lst, fst, gst, dst, alive,
                                       dead=dead, start=start)
        while lag_of(gst):
            gst, fst, _n, _l = sync_mask(gst, fst, jnp.asarray(alive))
        assert_converged(lst, fst, lanes=alive)
        assert int(np.asarray(gst.dropped)[0]) == 0, \
            "cascading promotion must lose zero acked windows"

    def test_winner_dies_after_fence_second_promote_recovers(self):
        """Kill between fence and re-publish: epoch+1 is burned but the
        ring was never taken over; promote #2 observes the half-finished
        epoch through the gather, fences epoch+2 and re-publishes."""
        lst, fst, gst, dst = self._seed()
        a1 = np.asarray([False, True, True, True])
        gst = gather_j(gst, alive_stacked(a1))
        gst = fence_j(gst, alive_stacked(a1))          # winner dies here
        a2 = np.asarray([False, False, True, True])
        gst, winner = promote_j(gst, alive_stacked(a2))
        assert int(np.asarray(winner)[0]) == 2
        assert int(np.asarray(mgr.runtime.run(log.epoch, gst))[0]) == 2, \
            "the burned epoch+1 must be observed, not reused"
        self._finish(lst, fst, gst, dst, a2, start=20)

    def test_winner_dies_mid_republish_limit_hook(self):
        """Kill mid-re-publish (limit=1 of a 2-entry suffix): the fence
        heads recover the true log end and promote #2 restarts the
        re-publish from the durable cursors."""
        lst, fst, gst, dst = self._seed()
        lst, gst = self._suffix(lst, gst, dead=(0, 1))
        a1 = np.asarray([False, True, True, True])
        gst = gather_j(gst, alive_stacked(a1))
        gst = fence_j(gst, alive_stacked(a1))
        gst, _w1 = repub_j(1)(gst, alive_stacked(a1))  # dies mid-suffix
        a2 = np.asarray([False, False, True, True])
        gst, winner = promote_j(gst, alive_stacked(a2))
        assert int(np.asarray(winner)[0]) == 2
        self._finish(lst, fst, gst, dst, a2, start=20)

    def test_simultaneous_leader_and_follower_kill(self):
        """Leader 0 and follower 2 die in the same window; the detector
        reaches the joint verdict and ONE promotion among the remaining
        live pair keeps serving, converged."""
        lst, fst, gst, dst = self._seed()
        alive = np.asarray([False, True, False, True])
        verdict = None
        for _w in range(THRESH):
            lst, fst, gst, dst, verdict, _ok, _n = hb_step(
                lst, fst, gst, dst, *WNOP, alive_stacked(alive))
        v = np.asarray(verdict)[0]
        assert not v[0] and not v[2] and v[1] and v[3], \
            "both deaths must land in the same verdict window"
        gst, winner = promote_j(gst, alive_stacked(v))
        assert int(np.asarray(winner)[0]) == 1
        self._finish(lst, fst, gst, dst, alive, start=30)

    @pytest.mark.torture
    def test_cascade_kill_point_sweep(self):
        """Double and triple cascades with the next kill at every
        promotion step boundary — after gather, after fence, and at each
        re-publish lane via the ``limit`` hook.  Zero acked-window loss
        and bitwise convergence everywhere."""
        def steps_upto(gst, alive, boundary):
            gst = gather_j(gst, alive_stacked(alive))
            if boundary == "gather":
                return gst
            gst = fence_j(gst, alive_stacked(alive))
            if boundary == "fence":
                return gst
            gst, _w = repub_j(int(boundary))(gst, alive_stacked(alive))
            return gst

        a1 = np.asarray([False, True, True, True])
        a2 = np.asarray([False, False, True, True])
        a3 = np.asarray([False, False, False, True])
        for boundary in ["gather", "fence", 0, 1, 2]:
            # double cascade: 0 dies, then winner 1 dies at `boundary`
            lst, fst, gst, dst = self._seed()
            lst, gst = self._suffix(lst, gst, dead=(0, 1))
            gst = steps_upto(gst, a1, boundary)
            gst, winner = promote_j(gst, alive_stacked(a2))
            assert int(np.asarray(winner)[0]) == 2, f"double @{boundary}"
            self._finish(lst, fst, gst, dst, a2, start=40)

            # triple cascade: winner 2 also dies at `boundary`
            lst, fst, gst, dst = self._seed()
            lst, gst = self._suffix(lst, gst, dead=(0, 1, 2))
            gst = steps_upto(gst, a1, boundary)
            gst = steps_upto(gst, a2, boundary)
            gst, winner = promote_j(gst, alive_stacked(a3))
            assert int(np.asarray(winner)[0]) == 3, f"triple @{boundary}"
            self._finish(lst, fst, gst, dst, a3, start=50)


class TestRejoin:
    def _kill_and_outrun(self, n_post=CAP + 2):
        """Kill node 0, promote via the detector verdict, then outrun its
        frozen cursor by more than ring capacity."""
        lst, fst, gst, dst = states()
        lst, fst, gst, dst, _v = drive(3, lst, fst, gst, dst, ALL,
                                       dead=())
        alive = np.asarray([False, True, True, True])
        verdict = None
        for _w in range(THRESH):
            lst, fst, gst, dst, verdict, _ok, _n = hb_step(
                lst, fst, gst, dst, *WNOP, alive_stacked(alive))
        v = np.asarray(verdict)[0]
        gst, _winner = promote_j(gst, alive_stacked(v))
        lst, fst, gst, dst, _v = drive(n_post, lst, fst, gst, dst, alive,
                                       start=20)
        return lst, fst, gst, dst, alive

    def _run_rejoin(self, gst, lst, fst, node=0, between=None):
        rst = log.rejoin_init()
        rounds = 0
        while not bool(np.asarray(rst.done)[0]):
            gst, rst, fst = rejoin_j(gst, rst, lst, fst, lane_arg(node))
            rounds += 1
            if between is not None:
                gst, lst, fst = between(rounds, gst, lst, fst)
            assert rounds < 96, "rejoin must terminate"
        return gst, rst, lst, fst, rounds

    def test_needs_snapshot_decision(self):
        lst, fst, gst, dst, _alive = self._kill_and_outrun()
        need = mgr.runtime.run(lambda s: log.needs_snapshot(s, 0), gst)
        assert bool(np.asarray(need)[0]), \
            "a cursor gap beyond ring capacity requires the snapshot path"
        lst2, fst2, gst2, dst2 = states()
        lst2, fst2, gst2, dst2, _v = drive(2, lst2, fst2, gst2, dst2, ALL,
                                           dead=())
        need2 = mgr.runtime.run(lambda s: log.needs_snapshot(s, 0), gst2)
        assert not bool(np.asarray(need2)[0]), \
            "a within-capacity gap replays from the ring tail"

    def test_snapshot_rejoin_converges_bitwise(self):
        lst, fst, gst, dst, _alive = self._kill_and_outrun()
        gst, rst, lst, fst, _rounds = self._run_rejoin(gst, lst, fst)
        assert int(np.asarray(rst.restarts)[0]) == 0, \
            "an uninterrupted transfer must not restart"
        assert_converged(lst, fst, what="post-rejoin")       # ALL lanes
        assert bool(np.asarray(gst.ring.alive)[0, 0]), \
            "rejoin must return the node to ring flow control"
        # the revived node serves again: full-membership convergence
        dst = mgr.runtime.run(lambda d: det.readmit(d, 0), dst)
        lst, fst, gst, dst, verdict = drive(3, lst, fst, gst, dst, ALL,
                                            dead=(), start=30)
        assert np.asarray(verdict)[0].all()
        while lag_of(gst):
            gst, fst, _n, _l = sync_mask(gst, fst, jnp.asarray(ALL))
        assert_converged(lst, fst, what="post-rejoin serving")

    def test_rejoin_racing_mutation_restarts_then_converges(self):
        """A mutation window mid-transfer moves the leader's head: the
        version stamp no longer matches, the staging restarts against
        the new base (resumability), and the rejoin still converges."""
        lst, fst, gst, dst, alive = self._kill_and_outrun()
        raced = {"n": 0}

        def racing(rounds, gst, lst, fst):
            if rounds == 2:
                out = hb_step(lst, fst, gst, dst, *mkw(40),
                              alive_stacked(alive))
                raced["n"] += 1
                return out[2], out[0], out[1]
            return gst, lst, fst

        gst, rst, lst, fst, _rounds = self._run_rejoin(gst, lst, fst,
                                                       between=racing)
        assert raced["n"] == 1
        assert int(np.asarray(rst.restarts)[0]) >= 1, \
            "the moved version stamp must restart the staging"
        assert_converged(lst, fst, what="post-race rejoin")

    def test_leader_death_mid_transfer_resumes_against_new_leader(self):
        """The cluster leader dies mid-transfer; promotion bumps the
        epoch, the stamp mismatch restarts the staging against the new
        leader, and the transfer completes."""
        lst, fst, gst, dst, _alive = self._kill_and_outrun()
        promoted = {"n": 0}

        def kill_leader(rounds, gst, lst, fst):
            if rounds == 2:
                a = np.asarray([False, False, True, True])
                gst, w = promote_j(gst, alive_stacked(a))
                assert int(np.asarray(w)[0]) == 2
                promoted["n"] += 1
            return gst, lst, fst

        gst, rst, lst, fst, _rounds = self._run_rejoin(
            gst, lst, fst, between=kill_leader)
        assert promoted["n"] == 1
        assert int(np.asarray(rst.restarts)[0]) >= 1, \
            "the epoch bump must restart the staging"
        assert_converged(lst, fst, what="post-failover rejoin")

    @pytest.mark.torture
    def test_rejoin_fuzz_interruptions(self):
        """Fuzz the transfer: deterministic schedules interleave racing
        mutation windows at varying rounds; every schedule restarts at
        least once and still converges bitwise."""
        rng = np.random.default_rng(7)
        for trial in range(4):
            lst, fst, gst, dst, alive = self._kill_and_outrun()
            race_at = int(rng.integers(1, 4))

            def interrupt(rounds, gst, lst, fst, at=race_at):
                if rounds == at:
                    out = hb_step(lst, fst, gst, dst, *mkw(60 + rounds),
                                  alive_stacked(alive))
                    return out[2], out[0], out[1]
                return gst, lst, fst

            gst, rst, lst, fst, _r = self._run_rejoin(gst, lst, fst,
                                                      between=interrupt)
            assert int(np.asarray(rst.restarts)[0]) >= 1, f"trial {trial}"
            assert_converged(lst, fst, what=f"fuzz trial {trial}")


class TestBoundedBackoff:
    def test_backoff_histogram_fast_path(self):
        """Uncontended appends land on attempt 0 — bucket 0 only."""
        lst, fst, gst, dst = states()
        lst, fst, gst, dst, _v = drive(3, lst, fst, gst, dst, ALL,
                                       dead=())
        hist = np.asarray(gst.retries_by_attempt)[0]
        assert hist[0] == 3 and hist[1:].sum() == 0

    @pytest.mark.parametrize("max_attempts", [1, 2, 3])
    def test_drop_then_recover_at_each_backoff_stage(self, max_attempts):
        """A wedged consumer defeats every attempt of the schedule (the
        window drops, once per attempt); the wedge lifts and re-appending
        the SAME window lands on attempt 1 after one backoff drain —
        drop-then-recover, with the histogram asserted exactly."""
        retry_j = _make_retry(max_attempts)
        kv_l, kv_f, gst, _dst = states()
        # wedge: lane 3 sync-masked (its cursor freezes) but ring-alive,
        # so flow control still counts it — the ring fills at CAP
        wedged = np.asarray([True, True, True, False])
        for i in range(CAP):
            kv_l, kv_f, gst, ok, _n = retry_j(
                kv_l, kv_f, gst, *mkw(i, dead=(3,)), alive_stacked(wedged))
            assert bool(np.asarray(ok)[0])
        # ring full, wedge holds: every attempt fails, one drop each
        kv_l, kv_f, gst, ok, _n = retry_j(
            kv_l, kv_f, gst, *mkw(CAP, dead=(3,)), alive_stacked(wedged))
        assert not bool(np.asarray(ok)[0])
        assert int(np.asarray(gst.dropped)[0]) == max_attempts
        assert int(np.asarray(gst.retries)[0]) == max_attempts - 1
        hist = np.asarray(gst.retries_by_attempt)[0]
        assert hist[0] == CAP and hist[1:].sum() == 0, \
            "failed schedules must not inflate the success histogram"
        # recover: lift the wedge and re-append the dropped window —
        # attempt 0 still sees the ring full, the first backoff drain
        # frees one slot, attempt 1 lands
        kv_l, kv_f, gst, ok, _n = retry_j(
            kv_l, kv_f, gst, *mkw(CAP, dead=(3,)), alive_stacked(ALL))
        hist = np.asarray(gst.retries_by_attempt)[0]
        if max_attempts == 1:
            assert not bool(np.asarray(ok)[0]), \
                "no retry budget → the still-full ring drops again"
            assert hist[1:].sum() == 0
        else:
            assert bool(np.asarray(ok)[0])
            assert hist[1] == 1, "recovery lands on attempt 1"
            assert int(np.asarray(gst.retries)[0]) == max_attempts


_RETRY = {}


def _make_retry(n):
    if n not in _RETRY:
        @jax.jit
        def f(lst, fst, gst, op, key, val, alive):
            def prog(lst, fst, gst, op, key, val, alive):
                me = mgr.runtime.my_id()
                lst, _res = leader.op_window(lst, op, key, val)
                gst, fst, ok, applied = log.append_with_retry(
                    gst, op, key, val, follower, fst, max_attempts=n,
                    pred=alive[gst.ring.owner], sync_pred=alive[me])
                return lst, fst, gst, ok, applied
            return mgr.runtime.run(prog, lst, fst, gst, op, key, val,
                                   alive)
        _RETRY[n] = f
    return _RETRY[n]
